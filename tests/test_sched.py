"""SLO-aware workload scheduler: admission control, fairness under slot
pressure, variance-guided chunk claiming.

Gates (ISSUE 4 acceptance):

* **parity** — with the neutral scheduler (infinite SLOs, uniform weights,
  default claim order) the scheduled server reproduces the unscheduled one
  round-for-round, bit-exactly, on the ref backend for packed and stream
  residency (single-device here; the SPMD side lives in a subprocess test);
* **pressure** — a high-priority late-arriving query meets a deadline the
  unscheduled FIFO server misses;
* **shed** — an infeasible-deadline query is shed and still returns a
  flagged synopsis-seeded estimate.
"""

import dataclasses
import json
import math
import os
import subprocess
import sys
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.engine import EngineConfig
from repro.core.queries import Linear, Query, Range
from repro.data.generator import make_synthetic_zipf, store_dataset
from repro.sched import (
    NEUTRAL,
    AdmissionController,
    P2Quantile,
    QuerySLO,
    SchedulerConfig,
    ServerLoad,
    ServiceTimeModel,
    WorkloadScheduler,
    max_min_weights,
    measured_slot_capacity,
    select_victim,
    slot_chunk_variances,
    variance_claim_order,
)
from repro.sched.admission import eq4_cost_terms, scan_tuples_per_s
from repro.serve.ola_server import (
    MeasuredRates,
    OLAWorkloadServer,
    ServerOptions,
    poisson_workload,
    select_plan,
)

COEF = tuple(1.0 / (k + 1) for k in range(8))


@pytest.fixture(scope="module")
def setup():
    vals = make_synthetic_zipf(4096, 8, seed=3)
    store = store_dataset(vals, 32, "ascii")
    return vals, store


def _truth_sum(vals):
    return float((vals @ np.asarray(COEF)).sum())


# ---------------------------------------------------------------------------
# Policy units
# ---------------------------------------------------------------------------

def test_max_min_weights_properties():
    act = np.array([True, True, True, False])
    pri = np.array([1.0, 1.0, 1.0, 1.0])
    # uncontended -> exactly 1.0 everywhere (the engine-parity precondition)
    np.testing.assert_array_equal(
        max_min_weights(pri, act, math.inf), np.ones(4))
    np.testing.assert_array_equal(
        max_min_weights(pri, act, 3.0), np.ones(4))
    # equal weights under contention -> equal split
    w = max_min_weights(pri, act, 1.5)
    np.testing.assert_allclose(w[:3], 0.5)
    assert w[3] == 1.0                       # inactive slots stay neutral
    # priority-proportional split: 1:4 at capacity 1.0 -> 0.2 / 0.8
    w = max_min_weights(np.array([1.0, 4.0]), np.array([True, True]), 1.0)
    np.testing.assert_allclose(w, [0.2, 0.8])
    # saturation: a dominant slot caps at 1.0 and the surplus water-fills
    w = max_min_weights(np.array([1.0, 100.0]), np.array([True, True]), 1.5)
    assert w[1] == 1.0
    np.testing.assert_allclose(w[0], 0.5)
    # total grant never exceeds capacity; every active slot makes progress
    w = max_min_weights(np.array([1.0, 2.0, 4.0]), np.ones(3, bool), 2.0)
    assert w.sum() <= 2.0 + 1e-9 and (w > 0).all()


def test_admission_required_tuples_and_decisions():
    rt = AdmissionController.required_tuples
    assert rt(0, math.inf, 0.05, 10_000) == 10_000       # no seed: full pass
    assert rt(100, 0.02, 0.05, 10_000) == 0.0            # seed already meets ε
    # CLT extrapolation: err halves when m quadruples
    assert rt(100, 0.10, 0.05, 10_000) == pytest.approx(300.0)
    assert rt(100, 0.10, 0.001, 200) == 100.0            # capped at the table

    ac = AdmissionController()
    load_free = ServerLoad(now=0.0, free_slots=1, queue_ahead=0,
                           scan_rate=1000.0, total_tuples=1000)
    load_busy = dataclasses.replace(load_free, free_slots=0)
    no_slo = QuerySLO()
    d = ac.decide(arrival_t=0.0, slo=no_slo, epsilon=0.05, load=load_free)
    assert d.action == "admitted"
    d = ac.decide(arrival_t=0.0, slo=no_slo, epsilon=0.05, load=load_busy)
    assert d.action == "queued"              # no deadline -> never shed
    # a deadline shorter than the (full-pass) service prediction -> shed...
    tight = QuerySLO(deadline_s=0.1)
    d = ac.decide(arrival_t=0.0, slo=tight, epsilon=0.05, load=load_free)
    assert d.action == "shed" and "deadline" in d.reason
    # ...unless a synopsis seed shows only a sliver of work remains
    d = ac.decide(arrival_t=0.0, slo=tight, epsilon=0.05, load=load_free,
                  seed_m=500, seed_err=0.052)
    assert d.action == "admitted"
    # shedding disabled degrades to queue
    d = AdmissionController(shed_enabled=False).decide(
        arrival_t=0.0, slo=tight, epsilon=0.05, load=load_busy)
    assert d.action == "queued"


def test_variance_claim_order_bands():
    """Unstarted chunks keep the committed order (band 0), started-open ones
    sort by variance desc (band 1), dead ones go last (band 2); the claimed
    prefix is never touched."""
    n = 8
    schedule = np.array([5, 2, 7, 0, 1, 3, 6, 4], np.int32)
    m = np.zeros((2, n))
    ys = np.zeros((2, n))
    yq = np.zeros((2, n))
    # chunks 0 and 1 started: chunk 1 has the larger within-variance
    m[:, [0, 1]] = 10
    ys[0, 0], yq[0, 0] = 10.0, 11.0          # var ~ 1/9
    ys[0, 1], yq[0, 1] = 10.0, 110.0         # var ~ 100/9
    state = SimpleNamespace(
        stats=SimpleNamespace(m=m, ysum=ys, ysq=yq),
        scan_m=np.array([10, 10, 0, 0, 0, 0, 0, 64]),
        closed=np.array([False] * 7 + [True]),
        head=2, schedule=schedule)
    sizes = np.full(n, 64)
    out = variance_claim_order(state, sizes)
    assert out is not None
    np.testing.assert_array_equal(out[:2], schedule[:2])  # prefix untouched
    # tail: never-started chunks first in committed order (unknown variance
    # counts as infinite, and first-touch order must stay a prefix of the
    # committed order), then started-open {0, 1} by variance (1 before 0),
    # then the exhausted chunk 7 last
    np.testing.assert_array_equal(out[2:], [3, 6, 4, 1, 0, 7])
    assert sorted(out.tolist()) == list(range(n))
    # nothing measured in the tail and nothing dead -> no reorder
    state2 = SimpleNamespace(
        stats=SimpleNamespace(m=np.zeros((2, n)), ysum=ys * 0, ysq=yq * 0),
        scan_m=np.zeros(n, int), closed=np.zeros(n, bool),
        head=0, schedule=schedule)
    assert variance_claim_order(state2, sizes) is None


def test_poisson_workload_deterministic():
    qs = [Query(agg="count", name=f"q{i}") for i in range(16)]
    a = poisson_workload(qs, rate_per_model_s=100.0, seed=42)
    b = poisson_workload(qs, rate_per_model_s=100.0, seed=42)
    assert [t for _, t in a] == [t for _, t in b]
    c = poisson_workload(qs, rate_per_model_s=100.0, seed=43)
    assert [t for _, t in a] != [t for _, t in c]
    # caller-owned rng: one stream split across two sections stays
    # reproducible end to end
    rng = np.random.default_rng(7)
    d1 = poisson_workload(qs[:8], 100.0, rng=rng)
    d2 = poisson_workload(qs[8:], 100.0, rng=rng)
    rng2 = np.random.default_rng(7)
    e = poisson_workload(qs, 100.0, rng=rng2)
    gaps = np.diff([0.0] + [t for _, t in d1]).tolist() \
        + np.diff([0.0] + [t for _, t in d2]).tolist()
    np.testing.assert_allclose(gaps, np.diff([0.0] + [t for _, t in e]))


# ---------------------------------------------------------------------------
# Parity gate: neutral scheduler == unscheduled server, round for round
# ---------------------------------------------------------------------------

def _mixed_workload():
    return [
        (Query(agg="sum", expr=Linear(COEF), epsilon=0.04, name="a"), 0.0),
        (Query(agg="sum", expr=Linear(COEF), pred=Range(0, 0.0, 8e7),
               epsilon=0.06, name="b"), 1e-5),
        (Query(agg="count", pred=Range(1, 0.0, 7e7), epsilon=0.08,
               name="c"), 2e-5),
        (Query(agg="avg", expr=Linear(COEF), epsilon=0.07, name="d"), 3e-5),
        (Query(agg="sum", expr=Linear(COEF), epsilon=0.10, name="e"), 4e-4),
    ]


@pytest.mark.parametrize("residency", ["packed", "stream"])
def test_neutral_scheduler_parity(setup, residency):
    """Scheduled server with the NEUTRAL config == unscheduled server:
    identical per-round scan trace and bit-identical results (ref backend),
    for both residencies — slots only ever see max_slots pressure here."""
    vals, store = setup
    cfg = EngineConfig(num_workers=2, seed=9, residency=residency)

    def run(scheduler):
        srv = OLAWorkloadServer(store, cfg, options=ServerOptions(max_slots=2))
        if scheduler is not None:
            srv.scheduler = scheduler           # same ctor state otherwise
        for q, at in _mixed_workload():
            srv.submit(q, arrival_t=at)
        trace = []
        res = srv.run(on_round=lambda s: trace.append(
            (int(s.tuples_scanned), int(np.asarray(s.state.head)))))
        out = [(r.qid, r.estimate, r.lo, r.hi, r.err, r.tuples_seen,
                r.t_admit, r.t_done, r.rounds_resident, r.sched_outcome,
                r.queue_wait, r.from_synopsis) for r in res]
        rounds, tuples = srv.rounds, srv.tuples_scanned
        srv.close()
        return out, trace, rounds, tuples

    base = run(None)
    neutral = run(WorkloadScheduler(NEUTRAL))
    assert neutral[1] == base[1], "per-round scan trace diverged"
    assert neutral[0] == base[0], "results diverged (must be bit-exact)"
    assert neutral[2:] == base[2:]


_SPMD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses, json
import numpy as np, jax
from repro.data.generator import make_synthetic_zipf, store_dataset
from repro.core.queries import Query, Linear, Range
from repro.core.engine import EngineConfig
from repro.serve.ola_server import OLAWorkloadServer, ServerOptions
from repro.sched import NEUTRAL, QuerySLO, SchedulerConfig, WorkloadScheduler

vals = make_synthetic_zipf(2048, 8, seed=3)
store = store_dataset(vals, 12, 'ascii', uneven=True)
coef = tuple(1.0/(k+1) for k in range(8))
cfg = EngineConfig(num_workers=8, budget_init=32, budget_min=32,
                   budget_max=32, seed=5)
mesh = jax.make_mesh((4,), ('data',))
active = SchedulerConfig(slot_capacity=1.5, claim_policy='variance',
                         shed_enabled=False, deadline_enforcement=False)

def serve(mesh=None, sched=None):
    srv = OLAWorkloadServer(store, cfg, options=ServerOptions(
        max_slots=3, synopsis_budget_tuples=0, mesh=mesh,
        scheduler=sched))
    srv.submit(Query(agg='sum', expr=Linear(coef), pred=Range(0, 0.0, 0.6e8),
                     epsilon=0.04), arrival_t=0.0)
    srv.submit(Query(agg='count', pred=Range(1, 0.0, 0.7e8), epsilon=0.06),
               arrival_t=0.0, slo=QuerySLO(priority='interactive'))
    srv.submit(Query(agg='avg', expr=Linear(coef), epsilon=0.05),
               arrival_t=1e-5, slo=QuerySLO(priority='batch'))
    res = srv.run(max_rounds=4000)
    return ([(r.qid, float(r.estimate), r.tuples_seen, r.sched_outcome)
             for r in res], srv.rounds)

plain_single = serve()
plain_spmd = serve(mesh=mesh)
neutral_spmd = serve(mesh=mesh, sched=WorkloadScheduler(NEUTRAL))
sched_single = serve(sched=WorkloadScheduler(active))
sched_spmd = serve(mesh=mesh, sched=WorkloadScheduler(active))
print(json.dumps({
  "spmd_matches_single": plain_spmd == plain_single,
  "neutral_parity_spmd": neutral_spmd == plain_spmd,
  "sched_spmd_matches_single": sched_spmd == sched_single,
  "sched_differs_from_plain": sched_single != plain_single,
}))
"""


def test_scheduler_spmd_parity():
    """On a forced 4-device CPU mesh: the neutral scheduler is bit-exact vs
    the unscheduled SPMD server, and the *active* scheduler (fairness
    contention + variance claims) produces identical results on SPMD and
    single-device — the claim reordering and per-slot weights preserve the
    deterministic hand-out."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SPMD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["spmd_matches_single"], res
    assert res["neutral_parity_spmd"], res
    assert res["sched_spmd_matches_single"], res


# ---------------------------------------------------------------------------
# Pressure: priority admission meets a deadline FIFO misses
# ---------------------------------------------------------------------------

def _pressure_run(store, slo_hot, scheduler):
    cfg = EngineConfig(num_workers=2, seed=13)
    srv = OLAWorkloadServer(
              store, cfg,
              options=ServerOptions(max_slots=1, synopsis_budget_tuples=0,
                  scheduler=scheduler))
    for i in range(3):
        srv.submit(Query(agg="sum", expr=Linear(COEF), epsilon=0.02,
                         name=f"long{i}"), arrival_t=0.0)
    srv.submit(Query(agg="sum", expr=Linear(COEF), epsilon=0.08, name="hot"),
               arrival_t=1e-6, slo=slo_hot)
    res = {r.name: r for r in srv.run()}
    srv.close()
    return res


def test_priority_pressure_meets_deadline(setup):
    """max_slots=1, three tight queries ahead: FIFO makes the late
    interactive query wait out the whole backlog; the priority scheduler
    admits it at the first slot hand-over, meeting a deadline FIFO misses."""
    vals, store = setup
    sched_cfg = SchedulerConfig(shed_enabled=False)
    # measure both policies on the same workload (no deadline yet)
    probe = QuerySLO(priority="interactive")
    lat_fifo = _pressure_run(store, probe, None)["hot"].latency
    lat_pri = _pressure_run(
        store, probe, WorkloadScheduler(sched_cfg))["hot"].latency
    assert lat_pri < lat_fifo, (lat_pri, lat_fifo)
    # a deadline between the two: scheduler meets it, FIFO provably misses
    deadline = (lat_pri + lat_fifo) / 2.0
    slo = QuerySLO(deadline_s=deadline, priority="interactive")
    res_pri = _pressure_run(store, slo, WorkloadScheduler(sched_cfg))
    res_fifo = _pressure_run(store, slo, None)
    assert res_pri["hot"].slo_met is True
    assert res_fifo["hot"].slo_met is False
    assert res_pri["hot"].sched_outcome == "queued"  # it did wait, once
    # the backlog still completes correctly under either policy (a tail
    # query can end unserved once the scan became a census — no synopsis
    # here — but every *answered* one must be accurate)
    truth = _truth_sum(vals)
    for res in (res_pri, res_fifo):
        answered = [res[f"long{i}"] for i in range(3)
                    if not res[f"long{i}"].unserved]
        assert len(answered) >= 2
        for r in answered:
            assert abs(r.estimate - truth) / truth < 3 * 0.02


def test_shed_returns_flagged_synopsis_estimate(setup):
    """An infeasible-deadline query is shed — never holds a slot — and its
    result is a flagged, synopsis-seeded best-effort estimate."""
    vals, store = setup
    truth = _truth_sum(vals)
    cfg = EngineConfig(num_workers=2, seed=17)
    srv = OLAWorkloadServer(
              store, cfg,
              options=ServerOptions(max_slots=2, synopsis_budget_tuples=4096,
                  scheduler=WorkloadScheduler(SchedulerConfig())))
    srv.submit(Query(agg="sum", expr=Linear(COEF), epsilon=0.04,
                     name="warm"), arrival_t=0.0)
    srv.run()
    scanned = srv.tuples_scanned
    # tighter ε than the synopsis delivers + a deadline far below the
    # predicted service -> shed
    t_full = store.num_tuples / srv._scan_rate
    srv.submit(Query(agg="sum", expr=Linear(COEF), epsilon=0.001,
                     name="doomed"),
               slo=QuerySLO(deadline_s=t_full * 1e-6))
    res = {r.name: r for r in srv.run()}
    doomed = res["doomed"]
    assert doomed.sched_outcome == "shed"
    assert doomed.from_synopsis and not doomed.unserved
    assert doomed.rounds_resident == 0
    assert srv.tuples_scanned == scanned        # zero extra raw access
    assert np.isfinite(doomed.estimate)
    assert abs(doomed.estimate - truth) / truth < 0.2   # best effort, sane
    assert doomed.err > 0.001                   # honestly flagged as short
    assert srv.shed_count == 1
    srv.close()


# ---------------------------------------------------------------------------
# Fairness under slot pressure
# ---------------------------------------------------------------------------

def test_fairness_weights_divide_round_budget(setup):
    """slot_capacity=1.0 with a batch and an interactive slot resident:
    weights must be 0.2/0.8 and the per-slot sample sizes must track the
    4:1 split (each slot counts a weight-proportional window prefix)."""
    vals, store = setup
    cfg = EngineConfig(num_workers=2, seed=19)
    sc = SchedulerConfig(slot_capacity=1.0, shed_enabled=False,
                         claim_policy="schedule")
    srv = OLAWorkloadServer(
              store, cfg,
              options=ServerOptions(max_slots=2, synopsis_budget_tuples=0,
                  scheduler=WorkloadScheduler(sc)))
    srv.submit(Query(agg="sum", expr=Linear(COEF), epsilon=1e-6, name="bat"),
               arrival_t=0.0, slo=QuerySLO(priority="batch"))
    srv.submit(Query(agg="sum", expr=Linear(COEF), epsilon=1e-6, name="hot"),
               arrival_t=0.0, slo=QuerySLO(priority="interactive"))
    for _ in range(4):
        srv.step()
    # priority intake: the interactive query was admitted first -> slot 0
    w = np.asarray(srv.table.weight)
    np.testing.assert_allclose(w, [0.8, 0.2], rtol=1e-5)
    m = np.asarray(srv.state.stats.m).sum(axis=1).astype(float)
    assert m[1] > 0
    assert 3.0 < m[0] / m[1] < 5.0, m           # ≈ 4:1 modulo per-window ceil
    # scan-level extraction is unaffected by the split (same chunks read)
    assert int(np.asarray(srv.state.scan_m).sum()) >= m.max()
    srv.close()


def test_deadline_enforcement_frees_slot(setup):
    """A query whose deadline lands mid-scan is retired at the deadline with
    the best estimate so far (finite, flagged unmet ε) instead of holding
    its slot."""
    vals, store = setup
    truth = _truth_sum(vals)
    cfg = EngineConfig(num_workers=2, seed=23)
    srv = OLAWorkloadServer(
              store, cfg,
              options=ServerOptions(max_slots=1, synopsis_budget_tuples=0,
                  scheduler=WorkloadScheduler(
                                SchedulerConfig(shed_enabled=False))))
    t_full = store.num_tuples / srv._scan_rate
    srv.submit(Query(agg="sum", expr=Linear(COEF), epsilon=1e-9,
                     name="boxed"),
               arrival_t=0.0, slo=QuerySLO(deadline_s=t_full * 0.25))
    res = srv.run()[0]
    assert res.tuples_seen < store.num_tuples   # stopped before the census
    assert np.isfinite(res.estimate)
    assert abs(res.estimate - truth) / truth < 0.25
    assert res.err > 1e-9                       # target honestly unmet
    assert res.slo_met is False                 # retired at, not within, t
    srv.close()


# ---------------------------------------------------------------------------
# Variance-guided claiming
# ---------------------------------------------------------------------------

def test_variance_claims_reorder_topup_and_stay_correct(setup):
    """A top-up pass under claim_policy="variance" reorders the re-opened
    tail — re-opened started chunks are claimed ahead of exhausted ones —
    while the schedule stays a permutation and the late tight query still
    converges to the truth.

    Phase 1 is a near-certain COUNT (within-chunk variance ≈ 0), whose local
    accuracy closes its chunks *early* (partially extracted); the tight SUM
    that follows drives the scan to wind-down and must re-open them."""
    vals, store = setup
    truth = _truth_sum(vals)
    cfg = EngineConfig(num_workers=2, seed=29)
    srv = OLAWorkloadServer(
              store, cfg,
              options=ServerOptions(max_slots=2, synopsis_budget_tuples=512,
                  scheduler=WorkloadScheduler(
                                SchedulerConfig(shed_enabled=False))))
    committed = np.asarray(srv.engine.program.schedule_np)
    srv.submit(Query(agg="count", pred=Range(0, 0.0, 1e12), epsilon=0.02,
                     name="loose"), arrival_t=0.0, plan="single_pass")
    srv.run()
    closed = np.asarray(srv.state.closed)
    scan_m = np.asarray(srv.state.scan_m)
    early = closed & (scan_m < np.asarray(store.chunk_sizes))
    assert early.sum() > 0, "phase 1 closed no chunk early"
    srv.submit(Query(agg="sum", expr=Linear(COEF), epsilon=0.005,
                     name="tight"), plan="single_pass")
    saw_reorder = []

    def watch(s):
        sched = np.asarray(s.state.schedule)
        assert sorted(sched.tolist()) == list(range(len(sched)))
        if not np.array_equal(sched, committed):
            saw_reorder.append(True)

    res = {r.name: r for r in srv.run(on_round=watch)}
    assert srv.topup_passes >= 1
    assert saw_reorder, "variance policy never reordered the claim tail"
    tight = res["tight"]
    assert abs(tight.estimate - truth) / truth < 3 * 0.005
    srv.close()


# ---------------------------------------------------------------------------
# Review regressions: honest accounting at the edges
# ---------------------------------------------------------------------------

def test_unserved_never_counts_as_slo_hit():
    """A NaN half-width (unserved result) is never an SLO hit, even for a
    deadline-only SLO — meeting a deadline with no answer is not service."""
    assert QuerySLO(deadline_s=1.0).met(0.1, float("nan")) is False
    assert QuerySLO(deadline_s=1.0).met(0.1, 5.0) is True
    assert QuerySLO().met(0.1, float("nan")) is False


def test_deadline_enforced_zero_tuple_slot_is_unserved(setup):
    """A query admitted after the scan became a census (no synopsis seed,
    nothing left to extract) and deadline-enforced before any round served
    it must retire flagged unserved with a NaN estimate — not a fabricated
    zero counted as an SLO hit."""
    vals, store = setup
    cfg = EngineConfig(num_workers=2, seed=31)
    srv = OLAWorkloadServer(
              store, cfg,
              options=ServerOptions(max_slots=1, synopsis_budget_tuples=0,
                  scheduler=WorkloadScheduler(
                                SchedulerConfig(shed_enabled=False))))
    srv.submit(Query(agg="sum", expr=Linear(COEF), epsilon=1e-9,
                     name="census"), arrival_t=0.0)
    # queued behind the census; its deadline expires while it waits, and by
    # the time it gets the slot there is nothing left to extract
    srv.submit(Query(agg="sum", expr=Linear(COEF), epsilon=0.05,
                     name="late"), arrival_t=0.0,
               slo=QuerySLO(deadline_s=1e-12))
    res = {r.name: r for r in srv.run()}
    assert res["census"].tuples_seen == store.num_tuples
    late = res["late"]
    assert late.unserved and np.isnan(late.estimate)
    assert late.tuples_seen == 0
    assert late.slo_met is False
    srv.close()


def test_admission_respects_target_halfwidth(setup):
    """Feasibility triage must judge against the *effective* ε a finite
    target_halfwidth implies, not the query's loose nominal ε: a query the
    seed already satisfies at ε=0.5 but whose half-width target demands far
    more data is shed when its deadline cannot cover that work."""
    vals, store = setup
    truth = _truth_sum(vals)
    cfg = EngineConfig(num_workers=2, seed=37)
    srv = OLAWorkloadServer(
              store, cfg,
              options=ServerOptions(max_slots=2, synopsis_budget_tuples=4096,
                  scheduler=WorkloadScheduler(SchedulerConfig())))
    srv.submit(Query(agg="sum", expr=Linear(COEF), epsilon=0.04,
                     name="warm"), arrival_t=0.0)
    srv.run()
    t_full = store.num_tuples / srv._scan_rate
    # nominal ε=0.5 is trivially met by the seed; the half-width target
    # (~0.1% relative) is not, and the deadline cannot cover the gap
    srv.submit(Query(agg="sum", expr=Linear(COEF), epsilon=0.5,
                     name="hw"),
               slo=QuerySLO(deadline_s=t_full * 1e-6,
                            target_halfwidth=abs(truth) * 1e-3))
    res = {r.name: r for r in srv.run()}
    assert res["hw"].sched_outcome == "shed"
    assert res["hw"].from_synopsis
    srv.close()


def test_fairness_weights_survive_slot_churn(setup):
    """Admitting a new query into a freed slot resets that row's table
    weight to 1.0; the scheduler must re-write the fair share even when the
    *computed* weight vector is unchanged — otherwise the new occupant runs
    at full budget for its whole residence (stale-cache regression)."""
    vals, store = setup
    cfg = EngineConfig(num_workers=2, seed=41)
    sc = SchedulerConfig(slot_capacity=1.0, shed_enabled=False,
                         claim_policy="schedule")
    srv = OLAWorkloadServer(
              store, cfg,
              options=ServerOptions(max_slots=2, synopsis_budget_tuples=0,
                  scheduler=WorkloadScheduler(sc)))
    # two equal-priority residents -> [0.5, 0.5]
    srv.submit(Query(agg="sum", expr=Linear(COEF), epsilon=1e-6, name="a"),
               arrival_t=0.0)
    srv.submit(Query(agg="count", pred=Range(0, 0.0, 1e12), epsilon=0.5,
                     name="b"), arrival_t=0.0)
    srv.step()
    w = np.asarray(srv.table.weight)
    assert w[0] == pytest.approx(0.5)       # a's contended fair share
    # b (a loose count) may retire within this very step; its cleared row
    # then resets to the neutral 1.0 (slot_table_clear keeps inactive slots
    # neutral so no contended weight leaks to the next occupant)
    assert w[1] == pytest.approx(1.0 if srv.slot_wq[1] is None else 0.5)
    # b retires fast (loose count); c takes its slot — same computed vector
    srv.submit(Query(agg="sum", expr=Linear(COEF), epsilon=1e-6, name="c"))
    for _ in range(6):
        srv.step()
        if any(w is not None and w.query.name == "c" for w in srv.slot_wq):
            break
    assert any(w is not None and w.query.name == "c" for w in srv.slot_wq)
    np.testing.assert_allclose(np.asarray(srv.table.weight), [0.5, 0.5])
    srv.close()


# ---------------------------------------------------------------------------
# Service-time model: quantile sketch + cold-start blend (ISSUE 5 tentpole)
# ---------------------------------------------------------------------------

def test_p2_quantile_tracks_percentile():
    """The P² sketch stays close to the exact empirical quantile on heavy
    -tailed streams — the service-time shape it exists for — and is exact
    below five observations."""
    for p, seed, draw in [(0.9, 0, "lognormal"), (0.5, 1, "lognormal"),
                          (0.9, 2, "exponential"), (0.75, 3, "uniform")]:
        rng = np.random.default_rng(seed)
        xs = getattr(rng, draw)(size=4000)
        sk = P2Quantile(p)
        for x in xs:
            sk.observe(x)
        exact = float(np.percentile(xs, 100 * p))
        assert sk.value() == pytest.approx(exact, rel=0.15), (p, draw)
    # exact small-sample path
    sk = P2Quantile(0.5)
    for x in (3.0, 1.0, 2.0):
        sk.observe(x)
    assert sk.value() == pytest.approx(2.0)
    assert P2Quantile(0.9).value() is None
    with pytest.raises(ValueError):
        P2Quantile(1.0)
    # regression: at EXACTLY five observations the markers are still the
    # raw sorted sample — a p90 over [1,1,1,1,100] must interpolate (~60),
    # not collapse to the median marker (1)
    sk = P2Quantile(0.9)
    for x in (1.0, 1.0, 1.0, 1.0, 100.0):
        sk.observe(x)
    assert sk.value() == pytest.approx(np.percentile(
        [1, 1, 1, 1, 100], 90, method="linear"))
    assert sk.value() > 50.0


def test_service_model_cold_start_blend():
    """predict() slides from the caller's prior to the class sketch as
    observations accumulate; unknown classes stay on the prior."""
    m = ServiceTimeModel(quantile=0.9, min_samples=4)
    assert m.predict("batch", 10.0) == 10.0          # no evidence: prior
    m.observe("batch", 2.0)
    # 1 of 4 samples: 25% sketch (2.0), 75% prior (10.0)
    assert m.predict("batch", 10.0) == pytest.approx(0.25 * 2.0 + 0.75 * 10.0)
    for _ in range(5):
        m.observe("batch", 2.0)
    assert m.predict("batch", 10.0) == pytest.approx(2.0)   # evidence wins
    assert m.predict("interactive", 7.0) == 7.0      # other classes untouched
    m.observe("batch", float("nan"))                 # garbage is ignored
    assert m.n_obs("batch") == 6


def test_admission_queue_priced_at_model_not_candidate():
    """Regression (ISSUE 5 bugfix): with no completed-query history, queued
    work ahead must be priced at the full-pass bound — not the candidate's
    own seed-discounted service — and with a trained model, at the class
    quantile."""
    ac = AdmissionController()
    load_busy = ServerLoad(now=0.0, free_slots=0, queue_ahead=2,
                           scan_rate=1000.0, total_tuples=10_000)
    full_pass = 10.0
    # candidate's seed says it needs almost nothing; 3 jobs ahead (occupant
    # + 2 queued) are full passes.  The old model priced them at the
    # candidate's ~0s service and predicted a feasible finish.
    slo = QuerySLO(deadline_s=5.0)
    d = ac.decide(arrival_t=0.0, slo=slo, epsilon=0.05, load=load_busy,
                  seed_m=5000, seed_err=0.051)
    assert d.predicted_finish_t >= 3 * full_pass
    assert d.action == "shed"
    # a model trained on fast completions for this class restores admission
    model = ServiceTimeModel(quantile=0.9, min_samples=4)
    for _ in range(8):
        model.observe("normal", 0.5)
    d = AdmissionController(service_model=model).decide(
        arrival_t=0.0, slo=slo, epsilon=0.05, load=load_busy,
        seed_m=5000, seed_err=0.051)
    assert d.action == "queued"
    # the server-priced components take precedence over the per-job fallback
    load_priced = dataclasses.replace(load_busy, slot_drain_s=0.25,
                                      queue_ahead_service_s=1.0)
    d = ac.decide(arrival_t=0.0, slo=slo, epsilon=0.05, load=load_priced,
                  seed_m=5000, seed_err=0.051)
    assert d.action == "queued"
    assert d.predicted_finish_t < 2.0


def test_quantile_admission_sheds_on_tail_not_mean(setup):
    """A bimodal service history (many fast, some near-full-pass) whose p90
    is slow: the quantile-priced wait sheds a deadline the mean would have
    accepted — the tentpole's 'shed on a quantile, not the mean' behavior."""
    vals, store = setup
    cfg = EngineConfig(num_workers=2, seed=43)
    srv = OLAWorkloadServer(
              store, cfg,
              options=ServerOptions(max_slots=1, synopsis_budget_tuples=0,
                  scheduler=WorkloadScheduler(SchedulerConfig())))
    t_full = store.num_tuples / srv._scan_rate
    model = srv.scheduler.service_model
    # observed history: 9 fast batch queries, 3 slow ones -> p90 ~ slow
    for _ in range(9):
        model.observe("normal", 0.05 * t_full)
    for _ in range(3):
        model.observe("normal", 0.9 * t_full)
    mean_service = (9 * 0.05 + 3 * 0.9) / 12 * t_full
    srv._service_times = [0.05 * t_full] * 9 + [0.9 * t_full] * 3
    # occupy the only slot so the candidate must wait
    srv.submit(Query(agg="sum", expr=Linear(COEF), epsilon=1e-6,
                     name="hold"), arrival_t=0.0)
    srv.step()
    # candidate: no seed (full-pass service), deadline covers service plus a
    # mean-priced wait but not a p90-priced one
    deadline = t_full + mean_service * 2.0
    srv.submit(Query(agg="sum", expr=Linear(COEF), epsilon=0.05,
                     name="edge"),
               slo=QuerySLO(deadline_s=deadline))
    res = {r.name: r for r in srv.run()}
    assert res["edge"].sched_outcome == "shed"
    srv.close()


# ---------------------------------------------------------------------------
# Measured-capacity fairness (ISSUE 5 tentpole)
# ---------------------------------------------------------------------------

def test_measured_slot_capacity_derivation():
    rates = MeasuredRates(io_bytes_per_sec=5e8, cpu_tuples_per_sec=3e5,
                          round_base_us=3000.0, round_slot_us=300.0)
    # headroom 0.5: half the scan-side round cost worth of slot evaluation
    assert measured_slot_capacity(rates, 0.5) == pytest.approx(5.0)
    assert measured_slot_capacity(rates, 1.0) == pytest.approx(10.0)
    # floor at 1.0: a lone slot always gets the full window
    tight = dataclasses.replace(rates, round_slot_us=30000.0)
    assert measured_slot_capacity(tight, 0.5) == 1.0
    # fit unavailable (old calibration / degenerate slope) -> None
    assert measured_slot_capacity(None) is None
    assert measured_slot_capacity(
        dataclasses.replace(rates, round_slot_us=0.0)) is None
    assert measured_slot_capacity(
        dataclasses.replace(rates, round_base_us=0.0)) is None
    with pytest.raises(ValueError):
        measured_slot_capacity(rates, headroom=0.0)


def test_scheduler_calibrate_binds_measured_capacity():
    rates = MeasuredRates(io_bytes_per_sec=5e8, cpu_tuples_per_sec=3e5,
                          round_base_us=3000.0, round_slot_us=500.0)
    sched = WorkloadScheduler(SchedulerConfig(slot_capacity="measured"))
    assert sched.fairness.slot_capacity == math.inf    # pre-calibration
    sched.calibrate(rates)
    assert sched.fairness.slot_capacity == pytest.approx(3.0)
    sched.calibrate(None)                              # lost calibration
    assert sched.fairness.slot_capacity == math.inf
    # hand-set capacities are never overridden
    fixed = WorkloadScheduler(SchedulerConfig(slot_capacity=2.0))
    fixed.calibrate(rates)
    assert fixed.fairness.slot_capacity == 2.0


def test_measured_capacity_drives_round_weights(setup):
    """A server built with slot_capacity="measured" and a calibration whose
    fit affords ~1 slot-unit must contend two residents (weights < 1),
    where an inf capacity would give both full budget."""
    vals, store = setup
    cfg = EngineConfig(num_workers=2, seed=47)
    rates = MeasuredRates(io_bytes_per_sec=5e8, cpu_tuples_per_sec=3e5,
                          round_base_us=1000.0, round_slot_us=500.0)
    srv = OLAWorkloadServer(
              store, cfg,
              options=ServerOptions(max_slots=2, synopsis_budget_tuples=0,
                  measured_rates=rates,
                  scheduler=WorkloadScheduler(SchedulerConfig(
            slot_capacity="measured", shed_enabled=False,
            claim_policy="schedule"))))
    assert srv.scheduler.fairness.slot_capacity == pytest.approx(1.0)
    srv.submit(Query(agg="sum", expr=Linear(COEF), epsilon=1e-6, name="a"),
               arrival_t=0.0, slo=QuerySLO(priority="batch"))
    srv.submit(Query(agg="sum", expr=Linear(COEF), epsilon=1e-6, name="b"),
               arrival_t=0.0, slo=QuerySLO(priority="interactive"))
    for _ in range(3):
        srv.step()
    w = np.asarray(srv.table.weight)
    np.testing.assert_allclose(w, [0.8, 0.2], rtol=1e-5)
    srv.close()


# ---------------------------------------------------------------------------
# Preemption (ISSUE 5 tentpole + acceptance gate)
# ---------------------------------------------------------------------------

def test_select_victim_policy():
    slos = [QuerySLO(priority="batch"), QuerySLO(priority="normal"),
            None, QuerySLO(priority="batch")]
    admit_t = [0.0, 1.0, 2.0, 3.0]
    hot = QuerySLO(deadline_s=1.0, priority="interactive")
    # lowest weight wins; among equal weights, the latest-admitted slot
    assert select_victim(hot, slos, admit_t, [True] * 4) == 3
    assert select_victim(hot, slos, admit_t, [True, True, True, False]) == 0
    # equal priority is never evicted
    norm = QuerySLO(deadline_s=1.0, priority="batch")
    assert select_victim(norm, slos, admit_t, [True] * 4) is None
    # no evictable slots
    assert select_victim(hot, slos, admit_t, [False] * 4) is None


def test_preemption_meets_deadline_only_with_it(setup):
    """ISSUE 5 acceptance: an interactive deadline that is feasible *only*
    with preemption — met with preempt=True, missed with the PR-4 behavior
    (preempt=False), and the evicted batch query still completes with an
    accurate answer, flagged sched_outcome="preempted"."""
    vals, store = setup
    truth = _truth_sum(vals)

    def serve(preempt: bool):
        cfg = EngineConfig(num_workers=2, seed=51)
        srv = OLAWorkloadServer(
                  store, cfg,
                  options=ServerOptions(max_slots=1,
                      synopsis_budget_tuples=0,
                      scheduler=WorkloadScheduler(SchedulerConfig(preempt=preempt))))
        t_full = store.num_tuples / srv._scan_rate
        # a near-census batch query holds the only slot...
        srv.submit(Query(agg="sum", expr=Linear(COEF), epsilon=1e-6,
                         name="bat"), arrival_t=0.0,
                   slo=QuerySLO(priority="batch"))
        # ...and an interactive query arrives whose deadline covers its own
        # (full-pass-bounded) service but not the batch occupant's drain
        srv.submit(Query(agg="sum", expr=Linear(COEF), epsilon=0.08,
                         name="hot"), arrival_t=t_full * 0.01,
                   slo=QuerySLO(deadline_s=t_full * 1.5,
                                priority="interactive"))
        res = {r.name: r for r in srv.run()}
        count = srv.preempt_count
        srv.close()
        return res, count

    res_pre, n_pre = serve(preempt=True)
    assert n_pre == 1
    assert res_pre["hot"].slo_met is True
    # the victim completed: re-admitted from its snapshot, never dropped
    bat = res_pre["bat"]
    assert bat.sched_outcome == "preempted"
    assert not bat.unserved and np.isfinite(bat.estimate)
    assert bat.seeded_tuples > 0          # snapshot seeded the re-admission
    # tuples scanned during its absence are lost to its sample (cursors
    # never rewind), so the census retires it with a small honest CI
    # rather than an exact answer — the estimate must still be inside it
    assert np.isfinite(bat.err) and bat.err < 0.05
    assert abs(bat.estimate - truth) / abs(truth) < 3 * max(bat.err, 1e-4)
    res_fifo, n_fifo = serve(preempt=False)
    assert n_fifo == 0
    assert res_fifo["hot"].slo_met is False


def test_preempt_never_evicts_for_hopeless_deadline(setup):
    """A deadline too tight even with a slot right now must shed, not
    evict: preemption that cannot save the candidate would only hurt the
    victim."""
    vals, store = setup
    cfg = EngineConfig(num_workers=2, seed=53)
    srv = OLAWorkloadServer(
              store, cfg,
              options=ServerOptions(max_slots=1, synopsis_budget_tuples=0,
                  scheduler=WorkloadScheduler(SchedulerConfig(preempt=True))))
    t_full = store.num_tuples / srv._scan_rate
    srv.submit(Query(agg="sum", expr=Linear(COEF), epsilon=1e-6,
                     name="bat"), arrival_t=0.0,
               slo=QuerySLO(priority="batch"))
    srv.submit(Query(agg="sum", expr=Linear(COEF), epsilon=0.08,
                     name="doomed"), arrival_t=t_full * 0.01,
               slo=QuerySLO(deadline_s=t_full * 1e-6,
                            priority="interactive"))
    res = {r.name: r for r in srv.run()}
    assert srv.preempt_count == 0
    assert res["doomed"].sched_outcome == "shed"
    assert res["bat"].sched_outcome == "admitted"
    srv.close()


# ---------------------------------------------------------------------------
# ε-distance-weighted variance claims (ISSUE 5 tentpole)
# ---------------------------------------------------------------------------

def test_eps_distance_weighting_flips_claim_key():
    """Two slots, two started chunks: the unweighted max key ranks chunk 0
    first (slot 0's huge variance), but slot 0 has already met its ε target
    (need 0) while far-from-target slot 1 cares about chunk 1 — the
    need-weighted key must flip the order."""
    n = 4
    m = np.zeros((2, n))
    ys = np.zeros((2, n))
    yq = np.zeros((2, n))
    m[:, [0, 1]] = 10
    ys[0, 0], yq[0, 0] = 10.0, 200.0         # slot 0: chunk 0 variance huge
    ys[1, 1], yq[1, 1] = 10.0, 60.0          # slot 1: chunk 1 variance modest
    state = SimpleNamespace(
        stats=SimpleNamespace(m=m, ysum=ys, ysq=yq),
        scan_m=np.array([10, 10, 0, 0]), closed=np.zeros(n, bool),
        head=2, schedule=np.array([2, 3, 0, 1], np.int32))
    vmax = slot_chunk_variances(state)
    assert vmax[0] > vmax[1]                 # unweighted: chunk 0 leads
    need = np.array([0.0, 3.0])              # slot 0 done, slot 1 at 4x ε
    vw = slot_chunk_variances(state, slot_need=need)
    assert vw[1] > vw[0] == 0.0              # weighted: chunk 1 leads
    out = variance_claim_order(state, np.full(n, 64), slot_need=need)
    np.testing.assert_array_equal(out, [2, 3, 1, 0])
    with pytest.raises(ValueError):
        slot_chunk_variances(state, slot_need=np.ones(3))


# ---------------------------------------------------------------------------
# eq4_cost_terms: one cost model for plan choice and admission (ISSUE 5)
# ---------------------------------------------------------------------------

def _stub_store(rng):
    sizes = rng.integers(8, 512, size=int(rng.integers(2, 40)))
    cost = float(rng.uniform(10.0, 5000.0))

    class Codec:
        record_bytes = int(rng.integers(16, 256))

        @staticmethod
        def extract_cost_per_tuple():
            return cost

    return SimpleNamespace(chunk_sizes=np.asarray(sizes), codec=Codec(),
                           num_tuples=int(sizes.sum()), num_chunks=len(sizes))


def test_eq4_cost_terms_shared_by_selectors():
    """Property (random-draw) test: select_plan's regime choice and the
    admission controller's scan rate are both pure functions of the SAME
    eq4_cost_terms output for any (store, config, rates) — a divergence
    would admit under one cost regime and plan under another."""
    rng = np.random.default_rng(101)
    for trial in range(60):
        store = _stub_store(rng)
        cfg = EngineConfig(num_workers=int(rng.integers(1, 16)),
                           io_bytes_per_sec=float(rng.uniform(1e6, 1e9)),
                           cpu_tuple_ops_per_sec=float(rng.uniform(1e7, 1e10)))
        rates = None
        if trial % 2:                        # measured-rates branch
            rates = MeasuredRates(
                io_bytes_per_sec=float(rng.uniform(1e6, 1e9)),
                cpu_tuples_per_sec=float(rng.uniform(1e3, 1e7)),
                workers=int(rng.integers(1, 16)),
                cost_per_tuple=float(rng.choice([0.0, rng.uniform(10, 5e3)])))
        t_io, t_cpu = eq4_cost_terms(store, cfg, rates)
        assert t_io > 0 and t_cpu > 0
        # deterministic: both callers see identical terms
        assert (t_io, t_cpu) == eq4_cost_terms(store, cfg, rates)
        # admission's scan rate is the overlapped-pipeline reading
        assert scan_tuples_per_s(store, cfg, rates) == pytest.approx(
            store.num_tuples / max(t_io, t_cpu))
        # select_plan's choice matches the regime the shared terms imply
        q = Query(agg="sum", expr=Linear((1.0,)),
                  epsilon=float(rng.choice([0.0, 0.05])))
        plan = select_plan(store, cfg, q, rates=rates)
        ratio = t_cpu / max(t_io, 1e-12)
        if q.epsilon <= 0:
            expect = "chunk_level"
        elif ratio < 0.5:
            expect = "holistic"
        elif ratio > 2.0:
            expect = "single_pass"
        else:
            expect = "resource_aware"
        assert plan == expect, (trial, ratio)


def test_eq4_cost_terms_rates_absent_fallback():
    """MeasuredRates-absent case: the modeled EngineConfig constants price
    the pass, and worker count divides only the CPU term."""
    rng = np.random.default_rng(7)
    store = _stub_store(rng)
    cfg = EngineConfig(num_workers=4, io_bytes_per_sec=1e8,
                       cpu_tuple_ops_per_sec=1e9)
    t_io, t_cpu = eq4_cost_terms(store, cfg, None)
    total_bytes = store.chunk_sizes.sum() * store.codec.record_bytes
    assert t_io == pytest.approx(total_bytes / 1e8)
    cfg2 = dataclasses.replace(cfg, num_workers=8)
    t_io2, t_cpu2 = eq4_cost_terms(store, cfg2, None)
    assert t_io2 == t_io
    assert t_cpu2 == pytest.approx(t_cpu / 2)
