"""Query AST and compiled evaluator."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.queries import (
    And, Cmp, Column, Custom, GroupEq, Having, Linear, Query, Range,
    SquaredDiff, TRUE, compile_queries, expand_group_by, linear_plan,
)


def test_evaluator_matches_numpy():
    rng = np.random.default_rng(0)
    cols = rng.uniform(-10, 10, (100, 4)).astype(np.float32)
    qs = [
        Query(agg="sum", expr=Linear((1.0, 2.0, 0.0, 0.0)),
              pred=Range(0, -5, 5)),
        Query(agg="count", pred=And((Cmp(1, ">", 0.0), Cmp(2, "<=", 3.0)))),
        Query(agg="sum", expr=SquaredDiff(0, 1), pred=TRUE),
    ]
    x, p = compile_queries(qs)(jnp.asarray(cols))
    sel0 = (cols[:, 0] >= -5) & (cols[:, 0] < 5)
    np.testing.assert_allclose(np.asarray(x[0]),
                               (cols[:, 0] + 2 * cols[:, 1]) * sel0, rtol=1e-5)
    sel1 = (cols[:, 1] > 0) & (cols[:, 2] <= 3)
    np.testing.assert_allclose(np.asarray(p[1]), sel1.astype(np.float32))
    np.testing.assert_allclose(np.asarray(x[2]),
                               (cols[:, 0] - cols[:, 1]) ** 2, rtol=1e-4)


def test_group_by_expansion():
    base = Query(agg="count", pred=Range(1, 0, 50), name="hits")
    with pytest.warns(DeprecationWarning):
        qs = expand_group_by(base, group_col=0,
                             group_values=[1.0, 2.0, 3.0])
    assert len(qs) == 3
    cols = jnp.asarray([[1.0, 10.0], [2.0, 10.0], [1.0, 99.0]], jnp.float32)
    x, p = compile_queries(qs)(cols)
    np.testing.assert_array_equal(np.asarray(p),
                                  [[1, 0, 0], [0, 1, 0], [0, 0, 0]])


def test_columns_used():
    q = Query(agg="sum", expr=Linear((1.0, 1.0)), pred=Range(3, 0, 1))
    assert q.columns_used == frozenset({0, 1, 3})
    q2 = Query(agg="sum", expr=Custom(lambda c: c[..., 0]))
    assert -1 in q2.columns_used  # unknown support -> full rebuild


def test_linear_plan():
    qs = [Query(agg="sum", expr=Linear((1.0, 0.5)), pred=Range(0, 2.0, 7.0)),
          Query(agg="count", pred=Cmp(1, ">=", 1.5))]
    plan = linear_plan(qs, 3)
    np.testing.assert_allclose(plan.coeffs[0], [1.0, 0.5, 0.0])
    assert plan.lo[0][0] == 2.0 and plan.hi[0][0] == 7.0
    assert plan.lo[1][1] == 1.5
    with pytest.raises(ValueError):
        linear_plan([Query(agg="sum", expr=SquaredDiff(0, 1))], 3)


def test_invalid_agg_rejected():
    with pytest.raises(ValueError):
        Query(agg="median")
