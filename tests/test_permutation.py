"""Feistel permutation properties (paper §4.1's in-memory shuffle)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.sampling.permutation import (
    chunk_seed,
    feistel_permute,
    feistel_permute_dyn,
    permutation_window,
    permutation_window_dyn,
    random_chunk_order,
)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 5000), seed=st.integers(0, 2**31 - 1))
def test_bijective(m, seed):
    out = np.asarray(feistel_permute(np.uint32(seed), jnp.arange(m), m))
    assert sorted(out.tolist()) == list(range(m))


@settings(max_examples=10, deadline=None)
@given(m=st.integers(2, 800), width=st.integers(0, 3), seed=st.integers(0, 1 << 30))
def test_dyn_matches_static_when_width_equals_m(m, width, seed):
    """Dynamic-domain variant is a bijection for any static width >= m."""
    w = m + width * 37
    out = np.asarray(feistel_permute_dyn(np.uint32(seed), jnp.arange(m), m, w))
    assert sorted(out.tolist()) == list(range(m))


def test_independent_chunk_orders():
    a = np.asarray(feistel_permute(chunk_seed(7, 0), jnp.arange(64), 64))
    b = np.asarray(feistel_permute(chunk_seed(7, 1), jnp.arange(64), 64))
    assert not np.array_equal(a, b)


def test_window_circular_wrap():
    seed = chunk_seed(3, 5)
    m = 50
    full = np.asarray(feistel_permute(seed, jnp.arange(m), m))
    w = np.asarray(permutation_window(seed, 45, 10, m))
    expect = np.concatenate([full[45:], full[:5]])
    np.testing.assert_array_equal(w, expect)
    w2 = np.asarray(permutation_window_dyn(seed, 45, 10, m, m))
    np.testing.assert_array_equal(w2, expect)


def test_deterministic_schedule():
    s1 = random_chunk_order(11, 100)
    s2 = random_chunk_order(11, 100)
    assert np.array_equal(s1, s2)
    assert sorted(s1.tolist()) == list(range(100))


def test_windows_partition_chunk():
    """Consecutive windows enumerate the whole chunk without replacement —
    the foundation of without-replacement incremental sampling."""
    seed = chunk_seed(1, 2)
    m = 37
    seen = []
    off = 0
    for b in (5, 7, 11, 14):
        seen.extend(np.asarray(permutation_window_dyn(seed, off, b, m, 64)).tolist())
        off += b
    assert sorted(seen) == list(range(m))
