"""Sharding rules + roofline parsing (multi-device parts run in subprocess)."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.roofline.analysis import collective_bytes, matmul_flops_from_hlo


def test_collective_bytes_parsing():
    hlo = """
  %ar = f32[1024,512]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%sum
  %ag = bf16[8,256]{1,0} all-gather(%y), replica_groups=[2,8]<=[16], dimensions={0}
  %cp = f32[64]{0} collective-permute(%z), source_target_pairs={{0,1}}
"""
    out = collective_bytes(hlo)
    assert out["count"] == 3
    ar = 2 * 1024 * 512 * 4 * (3 / 4)
    ag = 8 * 256 * 2 * (7 / 8)
    cp = 64 * 4
    assert out["all-reduce"] == pytest.approx(ar)
    assert out["all-gather"] == pytest.approx(ag)
    assert out["collective-permute"] == pytest.approx(cp)
    assert out["total"] == pytest.approx(ar + ag + cp)


def test_matmul_flops_parsing():
    hlo = """
ENTRY %main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %p1 = f32[256,64]{1,0} parameter(1)
  %d = f32[128,64]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    out = matmul_flops_from_hlo(hlo)
    assert out["dot_count"] == 1
    assert out["matmul_flops"] == 2 * 128 * 64 * 256
    assert out["dot_unresolved"] == 0


_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import build_model
from repro.distributed.sharding import rules_for, param_shardings
from repro.launch.steps import build_cell, lower_cell
import dataclasses

mesh = jax.make_mesh((4, 2), ("data", "model"))
report = {}
for arch in ["smollm-135m", "mixtral-8x7b", "zamba2-1.2b", "xlstm-125m"]:
    cfg = get_config(arch, tp=2, reduced=True)
    cfg = dataclasses.replace(cfg, d_model=128, d_ff=256 if cfg.d_ff else 0)
    model = build_model(cfg)
    params, specs = model.init(jax.random.PRNGKey(0))
    sh = param_shardings(params, specs, rules_for(cfg.family), mesh)
    leaves = jax.tree.leaves(sh)
    n_sharded = sum(1 for s in leaves
                    if any(p is not None for p in s.spec))
    report[arch] = {"params": len(leaves), "sharded": n_sharded}

# one real lowered cell on the small mesh: correctness of the whole path
cell = build_cell("smollm-135m", "train_4k", mesh, unroll_for_cost=False)
lowered = lower_cell(cell)
compiled = lowered.compile()
ca = compiled.cost_analysis()
if isinstance(ca, list):  # older jax returns [dict]
    ca = ca[0]
report["cell_ok"] = ca["flops"] > 0
print(json.dumps(report))
"""


@pytest.mark.slow
def test_sharding_rules_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    rep = json.loads(out.stdout.strip().splitlines()[-1])
    assert rep["cell_ok"]
    assert rep["smollm-135m"]["sharded"] > 0
    assert rep["mixtral-8x7b"]["sharded"] > 0
    assert rep["xlstm-125m"]["sharded"] == 0   # replicated by design
