"""Pallas kernels: shape/dtype sweeps, interpret-mode vs pure-jnp oracle."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.queries import Linear, Query, Range, TRUE, linear_plan
from repro.data.formats import AsciiFixedFormat
from repro.kernels import chunk_agg, extract_parse, round_stats
from repro.kernels import ref as R

RTOL = 2e-5


def _plan(num_cols, nq=2):
    qs = [Query(agg="sum", expr=Linear((1.0,) * num_cols),
                pred=Range(0, -500.0, 500.0)),
          Query(agg="count", pred=TRUE)][:nq]
    return linear_plan(qs, num_cols)


@pytest.mark.parametrize("t", [1, 7, 255, 256, 300])
@pytest.mark.parametrize("c", [1, 3, 8, 16])
def test_extract_parse_sweep(t, c):
    rng = np.random.default_rng(t * 31 + c)
    fmt = AsciiFixedFormat(c)
    vals = rng.uniform(-1e6, 1e6, (t, c))
    raw = jnp.asarray(fmt.encode(vals))
    a = np.asarray(extract_parse(raw, c, backend="pallas"))
    b = np.asarray(extract_parse(raw, c, backend="ref"))
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-5)
    np.testing.assert_allclose(a, vals, rtol=1e-5, atol=1e-2)


@pytest.mark.parametrize("n,m", [(1, 50), (3, 256), (5, 300)])
@pytest.mark.parametrize("c", [4, 8])
def test_chunk_agg_sweep(n, m, c):
    rng = np.random.default_rng(n * 100 + m + c)
    fmt = AsciiFixedFormat(c)
    raw = np.stack([fmt.encode(rng.uniform(-1000, 1000, (m, c)))
                    for _ in range(n)])
    sizes = rng.integers(1, m + 1, n).astype(np.int32)
    plan = _plan(c)
    a = np.asarray(chunk_agg(jnp.asarray(raw), sizes, plan.coeffs, plan.lo,
                             plan.hi, backend="pallas"))
    b = np.asarray(chunk_agg(jnp.asarray(raw), sizes, plan.coeffs, plan.lo,
                             plan.hi, backend="ref"))
    np.testing.assert_allclose(a, b, rtol=RTOL, atol=1e-2)
    # count column == sizes
    np.testing.assert_allclose(a[:, 0, 0], sizes, rtol=1e-6)


@pytest.mark.parametrize("w,b", [(1, 8), (4, 32), (7, 64)])
def test_round_stats_sweep(w, b):
    c = 6
    rng = np.random.default_rng(w * 10 + b)
    fmt = AsciiFixedFormat(c)
    slab = np.stack([fmt.encode(rng.uniform(-1000, 1000, (b, c)))
                     for _ in range(w)])
    beff = rng.integers(0, b + 1, w).astype(np.int32)
    plan = _plan(c)
    a = np.asarray(round_stats(jnp.asarray(slab), beff, plan.coeffs, plan.lo,
                               plan.hi, backend="pallas"))
    rr = np.asarray(round_stats(jnp.asarray(slab), beff, plan.coeffs, plan.lo,
                                plan.hi, backend="ref"))
    np.testing.assert_allclose(a, rr, rtol=RTOL, atol=1e-2)
    np.testing.assert_allclose(a[:, 0, 0], beff, rtol=1e-6)


def test_chunk_agg_matches_brute_force():
    """End-to-end semantic check against a numpy recompute."""
    c, n, m = 4, 3, 128
    rng = np.random.default_rng(0)
    fmt = AsciiFixedFormat(c)
    data = [rng.uniform(-1000, 1000, (m, c)) for _ in range(n)]
    raw = np.stack([fmt.encode(d) for d in data])
    sizes = np.asarray([m, 77, 5], np.int32)
    plan = _plan(c, nq=1)
    out = np.asarray(chunk_agg(jnp.asarray(raw), sizes, plan.coeffs, plan.lo,
                               plan.hi, backend="pallas"))
    for j in range(n):
        d = data[j][: sizes[j]]
        sel = (d[:, 0] >= -500) & (d[:, 0] < 500)
        x = d.sum(1) * sel
        np.testing.assert_allclose(out[j, 0, 1], x.sum(), rtol=1e-4)
        np.testing.assert_allclose(out[j, 0, 2], (x ** 2).sum(), rtol=1e-4)
        np.testing.assert_allclose(out[j, 0, 3], sel.sum(), rtol=1e-6)
