"""SPMD engine equivalence: 8 virtual devices == single device, bit-exact.

Runs in a subprocess because XLA_FLAGS must be set before jax initializes.
"""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np, jax
from repro.data.generator import make_synthetic_zipf, store_dataset
from repro.core.queries import Query, Linear, Range
from repro.core.engine import OLAEngine, EngineConfig
from repro.core.engine_spmd import SPMDEngine

vals = make_synthetic_zipf(4096, 8, seed=3)
store = store_dataset(vals, 16, 'ascii', uneven=True)
coef = tuple(1.0/(k+1) for k in range(8))
q = Query(agg='sum', expr=Linear(coef), pred=Range(0, 0.0, 0.5e8), epsilon=0.05)
cfg = EngineConfig(num_workers=8, strategy='single_pass', budget_init=64,
                   seed=5, cache_cap=32)
eng1 = OLAEngine(store, [q], cfg)
s1, h1 = eng1.run(max_rounds=300)
mesh = jax.make_mesh((8,), ('data',))
eng2 = SPMDEngine(store, [q], cfg, mesh)
s2, h2 = eng2.run(max_rounds=300)
e1 = np.array([float(r.estimate[0]) for r in h1])
e2 = np.array([float(r.estimate[0]) for r in h2])
cache_diff = float(np.abs(np.asarray(s1.cache) - np.asarray(s2.cache)).max())
print(json.dumps({
    "rounds": [len(h1), len(h2)],
    "max_est_diff": float(np.abs(e1[:min(len(e1),len(e2))] - e2[:min(len(e1),len(e2))]).max()),
    "same_len": len(h1) == len(h2),
    "cache_diff": cache_diff,
}))
"""


@pytest.mark.slow
def test_spmd_bit_exact_vs_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["same_len"], res
    assert res["max_est_diff"] == 0.0, res
    assert res["cache_diff"] == 0.0, res


_SLOT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np, jax
from repro.data.generator import (make_synthetic_zipf, make_wiki_like,
                                  store_dataset)
from repro.core.queries import (Query, Linear, Range, GroupBy,
                                empty_slot_table, encode_slot,
                                slot_table_set)
from repro.core.engine import SlotOLAEngine, EngineConfig
from repro.core.engine_spmd import SlotSPMDEngine
from repro.serve.ola_server import OLAWorkloadServer, ServerOptions

vals = make_synthetic_zipf(2048, 8, seed=3)
store = store_dataset(vals, 12, 'ascii', uneven=True)
coef = tuple(1.0/(k+1) for k in range(8))
q0 = Query(agg='sum', expr=Linear(coef), pred=Range(0, 0.0, 0.6e8), epsilon=0.04)
q1 = Query(agg='count', pred=Range(1, 0.0, 0.7e8), epsilon=0.06)
q2 = Query(agg='avg', expr=Linear(coef), epsilon=0.05)
# fixed t_eval: one jitted step per engine (bounds subprocess compile time)
cfg = EngineConfig(num_workers=8, budget_init=32, budget_min=32,
                   budget_max=32, seed=5, cache_cap=16)
mesh = jax.make_mesh((4,), ('data',))

def drive(engine):
    # deterministic slot-table driver with a mid-scan admission at round 3
    table = empty_slot_table(4, 8)
    table = slot_table_set(table, 0, encode_slot(q0, 8, plan='single_pass'))
    table = slot_table_set(table, 1, encode_slot(q1, 8, plan='single_pass'))
    state = engine.init_state()
    ests, curs = [], []
    for r in range(24):
        if r == 3:
            table = slot_table_set(table, 2,
                                   encode_slot(q2, 8, plan='single_pass'))
        b = engine.budget_ladder(float(state.budget))
        state, data = engine.round_data(state)
        state, rep = engine.round_fn(b)(state, table, data,
                                        engine.speeds)
        ests.append(np.asarray(rep.estimate))
        curs.append(np.asarray(state.cur))
    return (np.stack(ests), np.stack(curs), np.asarray(state.stats.m),
            np.asarray(state.scan_m))

e1 = drive(SlotOLAEngine(store, 4, cfg))
e2 = drive(SlotSPMDEngine(store, 4, cfg, mesh))
# streaming residency: the slab shards over the mesh worker axis; hand-out
# and stats must stay bit-exact vs the single-device packed drive
import dataclasses
cfg_stream = dataclasses.replace(cfg, residency='stream')
e3 = drive(SlotSPMDEngine(store, 4, cfg_stream, mesh))

# grouped slot plane: per-cell stats, CIs, and discovery tallies must be
# bit-exact across the mesh (tallies shard over workers then all-reduce)
wv, _ = make_wiki_like(2048, num_languages=12, seed=7)
store_g = store_dataset(wv, 8, 'ascii', uneven=True)
cfg_g = dataclasses.replace(cfg, max_groups=4)
qg = Query(agg='sum', expr=Linear((0.0, 1.0, 0.0, 0.0)), epsilon=0.03,
           group_by=GroupBy(col=0, max_groups=4, top_k=2,
                            values=[0.0, 1.0, 2.0]))

def drive_g(engine):
    table = empty_slot_table(2, 4, max_groups=4)
    table = slot_table_set(table, 0,
                           encode_slot(qg, 4, plan='single_pass',
                                       max_groups=4))
    state = engine.init_state()
    gests, gtals = [], []
    for r in range(10):
        b = engine.budget_ladder(float(state.budget))
        state, data = engine.round_data(state)
        state, rep = engine.round_fn(b)(state, table, data,
                                        engine.speeds)
        gests.append(np.asarray(rep.g_est))
        gtals.append(np.asarray(rep.g_tal))
    return (np.stack(gests), np.stack(gtals), np.asarray(state.gm),
            np.asarray(state.gys))

g1 = drive_g(SlotOLAEngine(store_g, 2, cfg_g))
g2 = drive_g(SlotSPMDEngine(store_g, 2, cfg_g, mesh))

# workload server over the SPMD engine == server over the single-device one
def serve(mesh=None):
    srv = OLAWorkloadServer(store, cfg, options=ServerOptions(
        max_slots=4, synopsis_budget_tuples=0, mesh=mesh))
    srv.submit(q0, arrival_t=0.0)
    srv.submit(q1, arrival_t=0.0)
    res = srv.run(max_rounds=4000)
    return [(r.qid, round(r.estimate, 3), r.tuples_seen) for r in res]

print(json.dumps({
    "est_diff": float(np.abs(e1[0] - e2[0]).max()),
    "handout_same": bool((e1[1] == e2[1]).all()),
    "m_same": bool((e1[2] == e2[2]).all()),
    "scan_m_same": bool((e1[3] == e2[3]).all()),
    "stream_est_diff": float(np.abs(e1[0] - e3[0]).max()),
    "stream_handout_same": bool((e1[1] == e3[1]).all()),
    "stream_m_same": bool((e1[2] == e3[2]).all()),
    "g_est_same": bool(np.array_equal(g1[0], g2[0], equal_nan=True)),
    "g_tal_same": bool((g1[1] == g2[1]).all()),
    "g_m_same": bool((g1[2] == g2[2]).all()),
    "g_ys_same": bool((g1[3] == g2[3]).all()),
    "server_single": serve(None),
    "server_spmd": serve(mesh),
}))
"""


def test_slot_spmd_parity_and_server():
    """SlotSPMDEngine on a forced 4-device CPU mesh hands out chunks in the
    same order and produces the same estimates as SlotOLAEngine, including a
    mid-scan admission; the workload server runs over either engine."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SLOT_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["handout_same"], res
    assert res["m_same"], res
    assert res["scan_m_same"], res
    assert res["est_diff"] == 0.0, res
    assert res["stream_handout_same"], res
    assert res["stream_m_same"], res
    assert res["stream_est_diff"] == 0.0, res
    assert res["g_est_same"], res
    assert res["g_tal_same"], res
    assert res["g_m_same"], res
    assert res["g_ys_same"], res
    assert res["server_spmd"] == res["server_single"], res
