"""SPMD engine equivalence: 8 virtual devices == single device, bit-exact.

Runs in a subprocess because XLA_FLAGS must be set before jax initializes.
"""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np, jax
from repro.data.generator import make_synthetic_zipf, store_dataset
from repro.core.queries import Query, Linear, Range
from repro.core.engine import OLAEngine, EngineConfig
from repro.core.engine_spmd import SPMDEngine

vals = make_synthetic_zipf(4096, 8, seed=3)
store = store_dataset(vals, 16, 'ascii', uneven=True)
coef = tuple(1.0/(k+1) for k in range(8))
q = Query(agg='sum', expr=Linear(coef), pred=Range(0, 0.0, 0.5e8), epsilon=0.05)
cfg = EngineConfig(num_workers=8, strategy='single_pass', budget_init=64,
                   seed=5, cache_cap=32)
eng1 = OLAEngine(store, [q], cfg)
s1, h1 = eng1.run(max_rounds=300)
mesh = jax.make_mesh((8,), ('data',))
eng2 = SPMDEngine(store, [q], cfg, mesh)
s2, h2 = eng2.run(max_rounds=300)
e1 = np.array([float(r.estimate[0]) for r in h1])
e2 = np.array([float(r.estimate[0]) for r in h2])
cache_diff = float(np.abs(np.asarray(s1.cache) - np.asarray(s2.cache)).max())
print(json.dumps({
    "rounds": [len(h1), len(h2)],
    "max_est_diff": float(np.abs(e1[:min(len(e1),len(e2))] - e2[:min(len(e1),len(e2))]).max()),
    "same_len": len(h1) == len(h2),
    "cache_diff": cache_diff,
}))
"""


@pytest.mark.slow
def test_spmd_bit_exact_vs_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["same_len"], res
    assert res["max_est_diff"] == 0.0, res
    assert res["cache_diff"] == 0.0, res
