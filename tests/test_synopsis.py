"""Bi-level sample synopsis (paper §6)."""

import numpy as np
import pytest

from repro.core.controller import EstimationController
from repro.core.engine import EngineConfig
from repro.core.queries import Custom, Linear, Query, Range, TRUE
from repro.core.synopsis import BiLevelSynopsis, SynopsisChunk
from repro.data.generator import make_synthetic_zipf, store_dataset
from repro.sampling.permutation import chunk_seed, feistel_permute

import jax.numpy as jnp


@pytest.fixture(scope="module")
def setup():
    vals = make_synthetic_zipf(4096, 8, seed=3)
    store = store_dataset(vals, 32, "ascii")
    return vals, store


COEF = tuple(1.0 / (k + 1) for k in range(8))


def test_budget_enforced_and_variance_allocation():
    syn = BiLevelSynopsis(n_chunks=4, num_cols=2, budget_tuples=100,
                          chunk_sizes=np.full(4, 1000))
    rng = np.random.default_rng(0)
    for j in range(4):
        syn.chunks[j] = SynopsisChunk(start=0, values=rng.normal(size=(50, 2)))
    variances = np.asarray([1.0, 1.0, 10.0, 0.1])
    syn._fit_budget(variances)
    assert syn.total_tuples <= 100
    # variance-driven: high-variance chunk keeps the most tuples
    assert syn.chunks[2].count > syn.chunks[3].count
    assert syn.chunks[2].count >= syn.chunks[0].count


def test_shrink_keeps_window_tail():
    """Dropping from the front preserves the permutation-window property."""
    syn = BiLevelSynopsis(n_chunks=2, num_cols=1, budget_tuples=10,
                          chunk_sizes=np.asarray([40, 40]))
    vals = np.arange(30, dtype=np.float64)[:, None]
    syn.chunks[0] = SynopsisChunk(start=0, values=vals.copy())
    syn.chunks[1] = SynopsisChunk(start=0, values=vals.copy())
    syn._fit_budget(np.asarray([1.0, 1.0]))
    ch = syn.chunks[0]
    assert ch.count <= 5 + 1
    # surviving values are the tail of the original window; start advanced
    np.testing.assert_array_equal(ch.values[:, 0],
                                  np.arange(30 - ch.count, 30))
    assert ch.start == 30 - ch.count


def test_seed_evaluates_new_query():
    syn = BiLevelSynopsis(n_chunks=3, num_cols=2, budget_tuples=1000,
                          chunk_sizes=np.full(3, 100))
    rng = np.random.default_rng(1)
    vals = rng.uniform(0, 10, (20, 2))
    syn.chunks[1] = SynopsisChunk(start=5, values=vals)
    q = Query(agg="sum", expr=Linear((2.0, 0.0)), pred=Range(1, 0.0, 5.0))
    seed = syn.seed([q], cache_cap=32)
    sel = (vals[:, 1] >= 0) & (vals[:, 1] < 5)
    np.testing.assert_allclose(seed["ysum"][0, 1],
                               (2 * vals[:, 0] * sel).sum(), rtol=1e-5)
    assert seed["m"][1] == 20
    assert seed["offset"][1] == 25     # cursor continues past the window


def test_plan_schedule_uncached_first():
    syn = BiLevelSynopsis(n_chunks=5, num_cols=1, budget_tuples=10,
                          chunk_sizes=np.full(5, 10))
    syn.chunks[0] = SynopsisChunk(start=0, values=np.zeros((2, 1)))
    syn.chunks[3] = SynopsisChunk(start=0, values=np.zeros((2, 1)))
    base = np.asarray([3, 1, 4, 0, 2])
    out = syn.plan_schedule(base)
    assert set(out[:3].tolist()) == {1, 4, 2}   # uncached first (orig order)
    assert out[:3].tolist() == [1, 4, 2]
    assert out[3:].tolist() == [3, 0]


def test_supports_and_rebuild():
    syn = BiLevelSynopsis(n_chunks=2, num_cols=3, budget_tuples=10,
                          chunk_sizes=np.full(2, 10))
    syn.columns_cached = frozenset({0, 1})
    assert syn.supports([Query(agg="sum", expr=Linear((1.0,)))])
    assert not syn.supports([Query(agg="sum", expr=Linear((1.0, 1.0, 1.0)))])
    assert not syn.supports([Query(agg="sum", expr=Custom(lambda c: c[..., 0]))])
    syn.chunks[0] = SynopsisChunk(start=0, values=np.zeros((2, 3)))
    syn.rebuild()
    assert len(syn.chunks) == 0 and syn.rebuilds == 1


def test_query_sequence_uses_synopsis(setup):
    """Paper Fig. 12 shape: repeat queries get cheaper through the synopsis."""
    vals, store = setup
    cfg = EngineConfig(num_workers=4, strategy="resource_aware",
                       budget_init=64, seed=5)
    ctrl = EstimationController(store, cfg, synopsis_budget_tuples=2048)
    q = Query(agg="sum", expr=Linear(COEF), epsilon=0.05)
    r1 = ctrl.run_query([q], max_rounds=4000)
    r2 = ctrl.run_query([q], max_rounds=4000)
    assert not r1.from_synopsis and r2.from_synopsis
    assert r2.chunks_ratio <= r1.chunks_ratio + 1e-9
    assert ctrl.synopsis.total_tuples <= 2048


def test_synopsis_window_consistency(setup):
    """Synopsis windows must equal the chunk's true permutation slice —
    guarantees later cursor continuation samples without replacement."""
    vals, store = setup
    cfg = EngineConfig(num_workers=4, strategy="single_pass",
                       budget_init=32, seed=7)
    ctrl = EstimationController(store, cfg, synopsis_budget_tuples=4096)
    q = Query(agg="sum", expr=Linear(COEF), epsilon=0.02)
    ctrl.run_query([q], max_rounds=4000)
    codec = store.codec
    for j, ch in list(ctrl.synopsis.chunks.items())[:5]:
        if ch.count == 0:
            continue
        m = int(store.chunk_sizes[j])
        seed = chunk_seed(cfg.seed, j)
        pos = (ch.start + np.arange(ch.count)) % m
        idx = np.asarray(feistel_permute(seed, jnp.asarray(pos), m))
        truth = np.asarray(codec.decode_ref(jnp.asarray(store.chunk_bytes(j))))[idx]
        np.testing.assert_allclose(ch.values, truth, rtol=1e-5)


def test_shrink_under_pressure_mid_flight(setup):
    """Budget pressure arriving *mid-scan* — between ``seed_slot`` (a slot
    was just seeded from the synopsis) and the next ``update_from_engine`` —
    must leave every surviving window a contiguous slice of its chunk's
    keyed permutation, so the seeded slot's future extraction stays a
    disjoint continuation (ISSUE 4 satellite)."""
    from repro.serve.ola_server import OLAWorkloadServer, ServerOptions

    vals, store = setup
    cfg = EngineConfig(num_workers=2, seed=21, strategy="single_pass",
                       budget_init=32)
    srv = OLAWorkloadServer(
              store, cfg,
              options=ServerOptions(max_slots=2, synopsis_budget_tuples=1024))
    srv.submit(Query(agg="sum", expr=Linear(COEF), epsilon=0.02,
                     name="warm"), arrival_t=0.0)
    for _ in range(4):                      # scan mid-flight, cache growing
        srv.step()
    syn = srv.synopsis
    srv._refresh_synopsis()
    assert syn.total_tuples > 0
    follow = Query(agg="sum", expr=Linear(COEF), pred=Range(0, 0.0, 8e7),
                   epsilon=0.08, name="late")
    seed = syn.seed_slot(follow)
    assert seed is not None and seed["m"].sum() > 0

    # budget pressure arrives now, before the next absorb: the window set
    # must shrink to the new budget with keep-the-tail semantics
    syn.budget = max(16, syn.total_tuples // 4)
    for _ in range(2):                      # scan continues mid-flight
        srv.step()
    srv._refresh_synopsis()                 # update_from_engine under pressure
    assert syn.total_tuples <= syn.budget

    checked = 0
    codec = store.codec
    for j, ch in syn.chunks.items():
        if ch.count == 0:
            continue
        m = int(store.chunk_sizes[j])
        sd = chunk_seed(cfg.seed, j)
        pos = (ch.start + np.arange(ch.count)) % m
        idx = np.asarray(feistel_permute(sd, jnp.asarray(pos), m))
        truth = np.asarray(codec.decode_ref(
            jnp.asarray(store.chunk_bytes(j))))[idx]
        np.testing.assert_allclose(ch.values, truth, rtol=1e-5)
        checked += 1
    assert checked > 0

    # the shrunk synopsis still seeds and serves the follow-up correctly
    srv.submit(follow)
    res = {r.name: r for r in srv.run()}
    sel = (vals[:, 0] >= 0) & (vals[:, 0] < 8e7)
    truth_f = float((vals @ np.asarray(COEF)) @ sel)
    assert abs(res["late"].estimate - truth_f) / abs(truth_f) < 3 * 0.08
    srv.close()
