"""CI benchmark regression gate (scripts/check_bench_regression.py).

The gate compares fresh smoke-lane BENCH_*.json artifacts against committed
baselines with per-field tolerance bands.  These tests drive the comparator
on synthetic fixtures (no benchmark run needed) and pin the acceptance
behavior: a seeded regression fails the gate, identical artifacts pass it,
a metric silently *disappearing* from the fresh run is itself a failure, a
metric with no baseline yet is informational (adding benchmark fields must
not break unrelated PRs), and machine-dependent bands (RSS) are skipped —
not failed — when the baseline came from a different runner.
"""

import copy
import importlib.util
import json
import os

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_spec = importlib.util.spec_from_file_location(
    "check_bench_regression",
    os.path.join(_ROOT, "scripts", "check_bench_regression.py"))
gate = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(gate)

_FP = {"cpu_model": "TestCPU v1", "cpu_count": 8,
       "python": "3.11.0", "jax": "0.4.0", "platform": "test"}


def _fresh_docs():
    return {
        "BENCH_workload.json": {
            "server": {"p95_latency_s": 0.002},
            "server_stream": {"p95_latency_s": 0.002},
            "sched": {
                "open_loop": {"scheduled": {"slo_hit_rate": 0.9}},
                "closed_loop": {
                    "scheduled": {"slo_hit_rate": 0.85,
                                  "p95_latency_s": 0.004},
                    "unscheduled": {"slo_hit_rate": 0.8},
                },
            },
            "rollup": {"rollup_hit_rate": 0.5,
                       "tier1_p95_latency_s": 0.001},
            "rescan": {
                "ascii": {"decoded_hit_rate": 0.9,
                          "hot_rescan_speedup": 3.0},
                "binary": {"decoded_hit_rate": 0.9},
            },
            "memory": {"peak_host_rss_bytes": 1_000_000},
            "fingerprint": dict(_FP),
        },
        "BENCH_slot_kernel.json": {
            "speedup_pallas_vs_ref": 2.5,
            "interpret_exempt": False,
            "memory": {"peak_host_rss_bytes": 500_000},
            "fingerprint": dict(_FP),
        },
    }


def test_identical_artifacts_pass():
    fresh = _fresh_docs()
    failures, lines = gate.compare(fresh, copy.deepcopy(fresh))
    assert failures == []
    assert any(line.startswith("OK") for line in lines)


def test_slo_hit_rate_band_is_2pp_absolute():
    fresh = _fresh_docs()
    base = copy.deepcopy(fresh)
    sched = fresh["BENCH_workload.json"]["sched"]["closed_loop"]["scheduled"]
    sched["slo_hit_rate"] = 0.85 - 0.019          # inside the band
    assert gate.compare(fresh, base)[0] == []
    sched["slo_hit_rate"] = 0.85 - 0.021          # outside
    failures, _ = gate.compare(fresh, base)
    assert failures == [
        "BENCH_workload.json:sched.closed_loop.scheduled.slo_hit_rate"]


def test_latency_and_rss_bands_are_relative():
    fresh = _fresh_docs()
    base = copy.deepcopy(fresh)
    fresh["BENCH_workload.json"]["server"]["p95_latency_s"] = 0.002 * 1.24
    fresh["BENCH_slot_kernel.json"]["memory"]["peak_host_rss_bytes"] = int(
        500_000 * 1.14)
    assert gate.compare(fresh, base)[0] == []
    fresh["BENCH_workload.json"]["server"]["p95_latency_s"] = 0.002 * 1.26
    fresh["BENCH_slot_kernel.json"]["memory"]["peak_host_rss_bytes"] = int(
        500_000 * 1.16)
    failures, _ = gate.compare(fresh, base)
    assert set(failures) == {
        "BENCH_workload.json:server.p95_latency_s",
        "BENCH_slot_kernel.json:memory.peak_host_rss_bytes"}


def test_rollup_bands():
    """ISSUE 6: rollup_hit_rate gates at -5pp absolute, tier-1 p95 latency
    at +25% relative — against the rollup smoke-lane baselines."""
    fresh = _fresh_docs()
    base = copy.deepcopy(fresh)
    roll = fresh["BENCH_workload.json"]["rollup"]
    roll["rollup_hit_rate"] = 0.5 - 0.049           # inside the band
    roll["tier1_p95_latency_s"] = 0.001 * 1.24
    assert gate.compare(fresh, base)[0] == []
    roll["rollup_hit_rate"] = 0.5 - 0.051           # outside
    roll["tier1_p95_latency_s"] = 0.001 * 1.26
    failures, _ = gate.compare(fresh, base)
    assert set(failures) == {
        "BENCH_workload.json:rollup.rollup_hit_rate",
        "BENCH_workload.json:rollup.tier1_p95_latency_s"}


def test_zero_tier1_latency_baseline_gets_absolute_ceiling():
    """Tier-1 answers are scan-free, so their modeled p95 can be exactly 0;
    a relative band over 0 would be vacuous (or reject any change).  The
    gate substitutes a small absolute ceiling."""
    fresh = _fresh_docs()
    base = copy.deepcopy(fresh)
    base["BENCH_workload.json"]["rollup"]["tier1_p95_latency_s"] = 0.0
    fresh["BENCH_workload.json"]["rollup"]["tier1_p95_latency_s"] = 0.0
    assert gate.compare(fresh, base)[0] == []
    near_free = gate.REL_GROW_ZERO_CEIL * 0.5
    fresh["BENCH_workload.json"]["rollup"]["tier1_p95_latency_s"] = near_free
    assert gate.compare(fresh, base)[0] == []
    scan_like = gate.REL_GROW_ZERO_CEIL * 20
    fresh["BENCH_workload.json"]["rollup"]["tier1_p95_latency_s"] = scan_like
    failures, _ = gate.compare(fresh, base)
    assert failures == ["BENCH_workload.json:rollup.tier1_p95_latency_s"]


def test_rescan_bands():
    """Decoded-cache lane: hit rate gates at -5pp absolute, the ASCII
    hot-rescan speedup at -20% relative."""
    fresh = _fresh_docs()
    base = copy.deepcopy(fresh)
    rescan = fresh["BENCH_workload.json"]["rescan"]
    rescan["ascii"]["decoded_hit_rate"] = 0.9 - 0.049     # inside
    rescan["ascii"]["hot_rescan_speedup"] = 3.0 * 0.81
    assert gate.compare(fresh, base)[0] == []
    rescan["ascii"]["decoded_hit_rate"] = 0.9 - 0.051     # outside
    rescan["ascii"]["hot_rescan_speedup"] = 3.0 * 0.79
    failures, _ = gate.compare(fresh, base)
    assert set(failures) == {
        "BENCH_workload.json:rescan.ascii.decoded_hit_rate",
        "BENCH_workload.json:rescan.ascii.hot_rescan_speedup"}


def test_compiled_band_gates_when_compiled_lane_ran():
    fresh = _fresh_docs()
    base = copy.deepcopy(fresh)
    fresh["BENCH_slot_kernel.json"]["speedup_pallas_vs_ref"] = 2.5 * 0.81
    assert gate.compare(fresh, base)[0] == []
    fresh["BENCH_slot_kernel.json"]["speedup_pallas_vs_ref"] = 2.5 * 0.79
    failures, _ = gate.compare(fresh, base)
    assert failures == ["BENCH_slot_kernel.json:speedup_pallas_vs_ref"]


def test_compiled_band_skips_on_interpret_only_runs():
    """An interpret-only fresh run (off-TPU CI: ``speedup_pallas_vs_ref``
    null, ``interpret_exempt`` true) must SKIP the compiled band — visibly,
    not silently absent — even against a TPU baseline with a real number."""
    base = _fresh_docs()
    for fresh_kern in ({"speedup_pallas_vs_ref": None,
                        "interpret_exempt": True},
                       {"speedup_pallas_vs_ref": 1.2,
                        "interpret_exempt": True}):
        fresh = _fresh_docs()
        fresh["BENCH_slot_kernel.json"].update(fresh_kern)
        failures, lines = gate.compare(fresh, copy.deepcopy(base))
        assert failures == []
        skips = [line for line in lines
                 if line.startswith("SKIP") and "speedup_pallas_vs_ref" in line]
        assert len(skips) == 1 and "compiled lane did not run" in skips[0]
    # a null baseline (committed from a CPU runner) is informational
    fresh = _fresh_docs()
    base = copy.deepcopy(fresh)
    base["BENCH_slot_kernel.json"]["speedup_pallas_vs_ref"] = None
    failures, lines = gate.compare(fresh, base)
    assert failures == []
    assert any(line.startswith("INFO") and "speedup_pallas_vs_ref" in line
               for line in lines)


def test_missing_fresh_metric_fails_missing_baseline_is_informational():
    fresh = _fresh_docs()
    base = copy.deepcopy(fresh)
    # baseline predates the field -> informational, not fail
    del base["BENCH_workload.json"]["sched"]["open_loop"]
    failures, lines = gate.compare(fresh, base)
    assert failures == []
    assert any(line.startswith("INFO") and "open_loop" in line
               for line in lines)
    # fresh run dropped a gated field -> fail
    del fresh["BENCH_workload.json"]["memory"]
    failures, _ = gate.compare(fresh, copy.deepcopy(_fresh_docs()))
    assert "BENCH_workload.json:memory.peak_host_rss_bytes" in failures
    # no baseline file at all -> all its checks informational
    failures, lines = gate.compare(_fresh_docs(), {})
    assert failures == []
    assert all(line.startswith("INFO") for line in lines)


def test_new_metric_without_baseline_does_not_gate():
    """Adding a benchmark field (a new gated metric whose baseline does not
    exist yet) must not fail unrelated PRs — it reports INFO until a
    baseline lands."""
    fresh = _fresh_docs()
    base = copy.deepcopy(fresh)
    del base["BENCH_workload.json"]["rollup"]    # baseline predates rollup
    failures, lines = gate.compare(fresh, base)
    assert failures == []
    info = [line for line in lines
            if line.startswith("INFO") and "rollup" in line]
    assert len(info) == 2                        # both rollup checks


def test_fingerprint_mismatch_skips_machine_checks_only():
    fresh = _fresh_docs()
    base = copy.deepcopy(fresh)
    # a memory regression on a *different* runner: not comparable -> SKIP
    fresh["BENCH_workload.json"]["memory"]["peak_host_rss_bytes"] = 10_000_000
    failures, lines = gate.compare(fresh, base, same_runner=False)
    assert failures == []
    skips = [line for line in lines if line.startswith("SKIP")]
    assert len(skips) == 2 and all("fingerprint" in s for s in skips)
    # ...but modeled-clock metrics still gate on any runner
    fresh["BENCH_workload.json"]["rollup"]["rollup_hit_rate"] = 0.1
    failures, _ = gate.compare(fresh, base, same_runner=False)
    assert failures == ["BENCH_workload.json:rollup.rollup_hit_rate"]


def test_fingerprints_match():
    fresh = _fresh_docs()
    base = copy.deepcopy(fresh)
    assert gate.fingerprints_match(fresh, base)
    # platform churn alone is not a mismatch (not in FINGERPRINT_KEYS)
    base["BENCH_workload.json"]["fingerprint"]["platform"] = "other"
    assert gate.fingerprints_match(fresh, base)
    base["BENCH_workload.json"]["fingerprint"]["cpu_model"] = "OtherCPU"
    assert not gate.fingerprints_match(fresh, base)
    # a baseline with no fingerprint at all is not comparable
    base = copy.deepcopy(fresh)
    del base["BENCH_slot_kernel.json"]["fingerprint"]
    assert not gate.fingerprints_match(fresh, base)
    # absent docs on either side don't block the comparison
    assert gate.fingerprints_match(fresh, {"BENCH_workload.json":
                                           fresh["BENCH_workload.json"]})


def test_seeded_regression_is_caught():
    """A seeded baseline bump (hit-rates +2x band, latency/RSS shrunk) must
    fail the gate — including the rollup hit-rate band, whose tolerance
    (5pp) is wider than the old flat +5pp seed bump."""
    fresh = _fresh_docs()
    seeded = gate.seeded_regression(fresh)
    failures, _ = gate.compare(fresh, seeded)
    assert failures, "the gate passed a seeded regression"
    assert any("slo_hit_rate" in f for f in failures)
    assert any("rollup_hit_rate" in f for f in failures)
    assert any("tier1_p95_latency_s" in f for f in failures)
    assert any("peak_host_rss_bytes" in f for f in failures)


def test_update_baselines_runs_all_smoke_lanes():
    calls = []

    class _Proc:
        returncode = 0

    def fake_runner(cmd, cwd=None, env=None):
        calls.append((cmd, cwd, env))
        return _Proc()

    rc = gate.update_baselines(runner=fake_runner)
    assert rc == 0
    assert len(calls) == len(gate.SMOKE_LANES)
    for (cmd, cwd, env), lane in zip(calls, gate.SMOKE_LANES):
        assert cmd[1:] == lane
        assert os.path.isdir(cwd)
        assert "src" in env["PYTHONPATH"]
    # a failing lane aborts with its exit code
    _Proc.returncode = 3
    assert gate.update_baselines(runner=fake_runner) == 3


@pytest.mark.parametrize("mode", ["pass", "fail", "self-test", "other-runner"])
def test_main_exit_codes(tmp_path, mode):
    fresh = _fresh_docs()
    fresh_dir = tmp_path / "fresh"
    base_dir = tmp_path / "base"
    fresh_dir.mkdir()
    base_dir.mkdir()
    base = copy.deepcopy(fresh)
    if mode == "fail":
        base["BENCH_workload.json"]["sched"]["closed_loop"]["scheduled"][
            "slo_hit_rate"] = 0.95
    if mode == "other-runner":
        # RSS regressed on a baseline from a different machine: skipped
        base["BENCH_workload.json"]["fingerprint"]["cpu_model"] = "OtherCPU"
        fresh["BENCH_workload.json"]["memory"]["peak_host_rss_bytes"] = 10**9
    for name, doc in fresh.items():
        (fresh_dir / name).write_text(json.dumps(doc))
    for name, doc in base.items():
        (base_dir / name).write_text(json.dumps(doc))
    if mode == "self-test":
        rc = gate.main(["--fresh-dir", str(fresh_dir), "--self-test"])
        assert rc == 0                     # seeded regression was caught
    else:
        rc = gate.main(["--fresh-dir", str(fresh_dir),
                        "--baseline-dir", str(base_dir)])
        assert rc == (1 if mode == "fail" else 0)
