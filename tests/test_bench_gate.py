"""CI benchmark regression gate (scripts/check_bench_regression.py).

The gate compares fresh smoke-lane BENCH_*.json artifacts against committed
baselines with per-field tolerance bands.  These tests drive the comparator
on synthetic fixtures (no benchmark run needed) and pin the ISSUE 5
acceptance behavior: a seeded regression fails the gate, identical
artifacts pass it, and a metric silently *disappearing* from the fresh run
is itself a failure.
"""

import copy
import importlib.util
import json
import os

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_spec = importlib.util.spec_from_file_location(
    "check_bench_regression",
    os.path.join(_ROOT, "scripts", "check_bench_regression.py"))
gate = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(gate)


def _fresh_docs():
    return {
        "BENCH_workload.json": {
            "server": {"p95_latency_s": 0.002},
            "server_stream": {"p95_latency_s": 0.002},
            "sched": {
                "open_loop": {"scheduled": {"slo_hit_rate": 0.9}},
                "closed_loop": {
                    "scheduled": {"slo_hit_rate": 0.85,
                                  "p95_latency_s": 0.004},
                    "unscheduled": {"slo_hit_rate": 0.8},
                },
            },
            "memory": {"peak_host_rss_bytes": 1_000_000},
        },
        "BENCH_slot_kernel.json": {
            "memory": {"peak_host_rss_bytes": 500_000},
        },
    }


def test_identical_artifacts_pass():
    fresh = _fresh_docs()
    failures, lines = gate.compare(fresh, copy.deepcopy(fresh))
    assert failures == []
    assert any(line.startswith("OK") for line in lines)


def test_slo_hit_rate_band_is_2pp_absolute():
    fresh = _fresh_docs()
    base = copy.deepcopy(fresh)
    sched = fresh["BENCH_workload.json"]["sched"]["closed_loop"]["scheduled"]
    sched["slo_hit_rate"] = 0.85 - 0.019          # inside the band
    assert gate.compare(fresh, base)[0] == []
    sched["slo_hit_rate"] = 0.85 - 0.021          # outside
    failures, _ = gate.compare(fresh, base)
    assert failures == [
        "BENCH_workload.json:sched.closed_loop.scheduled.slo_hit_rate"]


def test_latency_and_rss_bands_are_relative():
    fresh = _fresh_docs()
    base = copy.deepcopy(fresh)
    fresh["BENCH_workload.json"]["server"]["p95_latency_s"] = 0.002 * 1.24
    fresh["BENCH_slot_kernel.json"]["memory"]["peak_host_rss_bytes"] = int(
        500_000 * 1.14)
    assert gate.compare(fresh, base)[0] == []
    fresh["BENCH_workload.json"]["server"]["p95_latency_s"] = 0.002 * 1.26
    fresh["BENCH_slot_kernel.json"]["memory"]["peak_host_rss_bytes"] = int(
        500_000 * 1.16)
    failures, _ = gate.compare(fresh, base)
    assert set(failures) == {
        "BENCH_workload.json:server.p95_latency_s",
        "BENCH_slot_kernel.json:memory.peak_host_rss_bytes"}


def test_missing_fresh_metric_fails_missing_baseline_skips():
    fresh = _fresh_docs()
    base = copy.deepcopy(fresh)
    # baseline predates the field -> skip, not fail
    del base["BENCH_workload.json"]["sched"]["open_loop"]
    failures, lines = gate.compare(fresh, base)
    assert failures == []
    assert any(line.startswith("SKIP") and "open_loop" in line
               for line in lines)
    # fresh run dropped a gated field -> fail
    del fresh["BENCH_workload.json"]["memory"]
    failures, _ = gate.compare(fresh, copy.deepcopy(_fresh_docs()))
    assert "BENCH_workload.json:memory.peak_host_rss_bytes" in failures
    # no baseline file at all -> all its checks skip
    failures, lines = gate.compare(_fresh_docs(), {})
    assert failures == []
    assert all(line.startswith("SKIP") for line in lines)


def test_seeded_regression_is_caught():
    """ISSUE 5 acceptance: a +5pp slo_hit_rate baseline bump (and shrunk
    latency/RSS baselines) must fail the gate."""
    fresh = _fresh_docs()
    seeded = gate.seeded_regression(fresh)
    failures, _ = gate.compare(fresh, seeded)
    assert failures, "the gate passed a seeded regression"
    assert any("slo_hit_rate" in f for f in failures)
    assert any("peak_host_rss_bytes" in f for f in failures)


@pytest.mark.parametrize("mode", ["pass", "fail", "self-test"])
def test_main_exit_codes(tmp_path, mode):
    fresh = _fresh_docs()
    fresh_dir = tmp_path / "fresh"
    base_dir = tmp_path / "base"
    fresh_dir.mkdir()
    base_dir.mkdir()
    base = copy.deepcopy(fresh)
    if mode == "fail":
        base["BENCH_workload.json"]["sched"]["closed_loop"]["scheduled"][
            "slo_hit_rate"] = 0.95
    for name, doc in fresh.items():
        (fresh_dir / name).write_text(json.dumps(doc))
    for name, doc in base.items():
        (base_dir / name).write_text(json.dumps(doc))
    if mode == "self-test":
        rc = gate.main(["--fresh-dir", str(fresh_dir), "--self-test"])
        assert rc == 0                     # seeded regression was caught
    else:
        rc = gate.main(["--fresh-dir", str(fresh_dir),
                        "--baseline-dir", str(base_dir)])
        assert rc == (1 if mode == "fail" else 0)
