"""Parity of the fused Pallas extraction path against the reference path.

``EngineConfig.extract_backend="pallas"`` routes the round's EXTRACT stage
(gather + parse + slot eval + partial stats) through the fused
``kernels/slot_extract.py`` kernel — in interpret mode on CPU, which is what
these tests (and the CI fast job) exercise.  The contract: the pallas engine
matches the ref engine's ``RoundReport`` and ``BiLevelStats`` to fp32
tolerance, round for round, in both query planes — the only difference is
float summation order inside the fused reductions.
"""

import dataclasses

import numpy as np
import pytest

jnp = pytest.importorskip("jax.numpy")

from repro.core.engine import EngineConfig, OLAEngine, SlotOLAEngine
from repro.core.queries import (
    And,
    Cmp,
    Linear,
    Query,
    Range,
    SquaredDiff,
    empty_slot_table,
    encode_slot,
    slot_table_set,
)
from repro.data.generator import make_synthetic_zipf, store_dataset
from repro.kernels.ops import slot_extract
from repro.serve.ola_server import OLAWorkloadServer, ServerOptions

COEF = tuple(1.0 / (k + 1) for k in range(8))
QUERIES = [
    Query(agg="sum", expr=Linear(COEF), pred=Range(0, 0.0, 0.6e8),
          epsilon=0.04),
    Query(agg="count", pred=Range(1, 0.0, 0.7e8), epsilon=0.06),
    Query(agg="avg", expr=Linear(COEF), epsilon=0.05),
]


def _store(t=2048, chunks=12, seed=3):
    # uneven chunk sizes: the final permutation window of every chunk is a
    # partial (padded) tile, and m_max is not a multiple of the budget ladder
    return store_dataset(make_synthetic_zipf(t, 8, seed=seed), chunks,
                         "ascii", uneven=True)


def _cfg(**kw):
    base = dict(num_workers=4, strategy="single_pass", budget_init=32,
                seed=5, cache_cap=16)
    base.update(kw)
    return EngineConfig(**base)


def _assert_report_close(ra, rb, rtol=2e-5):
    for name in ra._fields:
        a, b = np.asarray(getattr(ra, name)), np.asarray(getattr(rb, name))
        np.testing.assert_allclose(a, b, rtol=rtol, atol=1e-6,
                                   err_msg=f"RoundReport.{name}")


def _assert_stats_close(sa, sb, rtol=2e-5):
    for name in ("m", "ysum", "ysq", "psum"):
        np.testing.assert_allclose(
            np.asarray(getattr(sa, name)), np.asarray(getattr(sb, name)),
            rtol=rtol, atol=1e-6, err_msg=f"BiLevelStats.{name}")


def test_kernel_matches_ref_oracle():
    """Kernel-level parity incl. zero budgets, inactive gates, COUNT slots."""
    rng = np.random.default_rng(0)
    from repro.data.formats import AsciiFixedFormat

    n, m, c, w, b, s = 6, 37, 8, 4, 16, 5   # m % tile != 0 by construction
    codec = AsciiFixedFormat(c)
    vals = rng.uniform(-1e7, 1e7, (n * m, c))
    packed = jnp.asarray(codec.encode(vals).reshape(n, m, codec.record_bytes))
    jw = rng.integers(0, n, w).astype(np.int32)
    idx = rng.integers(0, m, (w, b)).astype(np.int32)
    b_eff = np.array([b, 7, 0, 3], np.int32)
    coeffs = rng.normal(size=(s, c)).astype(np.float32)
    lo = np.full((s, c), -np.inf, np.float32)
    hi = np.full((s, c), np.inf, np.float32)
    lo[:, 0] = rng.uniform(-1e7, 0, s)
    hi[:, 0] = rng.uniform(0, 1e7, s)
    is_count = np.array([0, 1, 0, 0, 1], np.float32)
    gate = np.array([1, 1, 0, 1, 0], np.float32)

    sr, cr = slot_extract(packed, jw, idx, b_eff, coeffs, lo, hi, is_count,
                          gate, return_cols=True, backend="ref")
    sp, cp = slot_extract(packed, jw, idx, b_eff, coeffs, lo, hi, is_count,
                          gate, return_cols=True, backend="pallas")
    np.testing.assert_allclose(np.asarray(sr), np.asarray(sp), rtol=2e-6)
    np.testing.assert_allclose(np.asarray(cr), np.asarray(cp), rtol=1e-6)
    # gated-off slots contribute exactly nothing
    assert np.all(np.asarray(sp)[:, 2, 1:] == 0.0)


def test_frozen_mode_parity():
    """OLAEngine pallas == ref per round: report, stats, and the synopsis
    extraction cache (fed by the kernel's decoded-slab output)."""
    store = _store()
    engines = {be: OLAEngine(store, QUERIES, _cfg(extract_backend=be))
               for be in ("ref", "pallas")}
    states = {be: e.init_state() for be, e in engines.items()}
    for _ in range(12):
        reps = {}
        for be, e in engines.items():
            b = e.budget_ladder(float(states[be].budget))
            states[be], reps[be] = e.round_fn(b)(states[be], e.packed,
                                                 e.speeds)
        _assert_report_close(reps["ref"], reps["pallas"])
    _assert_stats_close(states["ref"].stats, states["pallas"].stats)
    np.testing.assert_allclose(np.asarray(states["ref"].cache),
                               np.asarray(states["pallas"].cache), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(states["ref"].scan_m),
                                  np.asarray(states["pallas"].scan_m))


def test_cmp_predicates_agree_across_backends():
    """`Cmp` boundary ops must lower to coefficient form *exactly* (closed
    bounds shift one f32 ulp), so ref and pallas agree tuple-for-tuple even
    on values equal to the threshold; '!=' has no range form and must raise
    at build, never be silently approximated."""
    # a table where column values land exactly on the comparison thresholds
    vals = np.zeros((256, 8))
    vals[:, 0] = np.tile([1.0, 2.0, 3.0, 4.0], 64)
    store = store_dataset(vals, 4, "ascii")
    qs = [Query(agg="count", pred=Cmp(0, "<=", 2.0), name="le"),
          Query(agg="count", pred=Cmp(0, ">", 2.0), name="gt"),
          Query(agg="count", pred=And((Cmp(0, ">=", 2.0), Cmp(0, "<", 4.0))),
                name="band"),
          Query(agg="count", pred=Cmp(0, "==", 3.0), name="eq")]
    finals = {}
    for be in ("ref", "pallas"):
        eng = OLAEngine(store, qs, _cfg(extract_backend=be, cache_cap=0,
                                        strategy="holistic"))
        state, _ = eng.run(max_rounds=50)
        finals[be] = np.asarray(state.stats.psum).sum(axis=1)
    np.testing.assert_array_equal(finals["ref"], finals["pallas"])
    assert finals["ref"][0] == 128  # <= includes the threshold value
    assert finals["ref"][1] == 128  # > excludes it
    with pytest.raises(ValueError, match="not range-encodable"):
        OLAEngine(store, [Query(agg="count", pred=Cmp(0, "!=", 2.0))],
                  _cfg(extract_backend="pallas"))


def test_frozen_mode_pallas_rejects_nonlinear():
    store = _store(t=512, chunks=4)
    q = Query(agg="sum", expr=SquaredDiff(0, 1), epsilon=0.05)
    with pytest.raises(ValueError, match="not linear"):
        OLAEngine(store, [q], _cfg(extract_backend="pallas"))
    OLAEngine(store, [q], _cfg(extract_backend="ref"))  # ref path still fine
    # the kernel accumulates in f32: a non-f32 stats dtype must fail loud on
    # the explicit backend (and 'auto' silently resolves to ref instead)
    with pytest.raises(ValueError, match="float32 stats"):
        OLAEngine(store, QUERIES[:1], _cfg(extract_backend="pallas",
                                           stats_dtype="bfloat16"))
    eng = OLAEngine(store, QUERIES[:1], _cfg(extract_backend="auto",
                                             stats_dtype="bfloat16"))
    assert not eng.program.extract_pallas


def test_slot_mode_parity_with_midscan_admission():
    """SlotOLAEngine pallas == ref round for round, with a query admitted
    mid-scan (round 4) and one retired early (round 8)."""
    store = _store()
    engines = {be: SlotOLAEngine(store, 4, _cfg(extract_backend=be))
               for be in ("ref", "pallas")}
    states = {be: e.init_state() for be, e in engines.items()}
    table = empty_slot_table(4, 8)
    table = slot_table_set(table, 0, encode_slot(QUERIES[0], 8,
                                                 plan="single_pass"))
    table = slot_table_set(table, 1, encode_slot(QUERIES[1], 8,
                                                 plan="single_pass"))
    for r in range(14):
        if r == 4:  # mid-scan admission into slot 2
            table = slot_table_set(table, 2, encode_slot(
                QUERIES[2], 8, plan="single_pass"))
        if r == 8:  # early retirement of slot 1
            table = table._replace(active=table.active.at[1].set(False))
        reps = {}
        for be, e in engines.items():
            b = e.budget_ladder(float(states[be].budget))
            states[be], reps[be] = e.round_fn(b)(states[be], table, e.packed,
                                                 e.speeds)
        _assert_report_close(reps["ref"], reps["pallas"])
    _assert_stats_close(states["ref"].stats, states["pallas"].stats)


def test_workload_server_on_pallas_backend():
    """End-to-end: the workload server (admission, synopsis seeding from the
    kernel-fed cache, retirement) answers the same queries on both backends."""
    store = _store()
    results = {}
    for be in ("ref", "pallas"):
        srv = OLAWorkloadServer(
                  store, _cfg(extract_backend=be),
                  options=ServerOptions(max_slots=4,
                      synopsis_budget_tuples=256))
        for q in QUERIES:
            srv.submit(q, arrival_t=0.0)
        res = srv.run(max_rounds=4000)
        assert not srv.truncated
        results[be] = res
    assert [r.qid for r in results["ref"]] == [r.qid for r in results["pallas"]]
    for ra, rb in zip(results["ref"], results["pallas"]):
        assert ra.tuples_seen == rb.tuples_seen, (ra, rb)
        np.testing.assert_allclose(ra.estimate, rb.estimate, rtol=2e-5)
        np.testing.assert_allclose(ra.err, rb.err, rtol=1e-3, atol=1e-6)


def test_auto_backend_resolves_off_tpu():
    """'auto' picks ref off-TPU — no interpret-mode overhead in production
    CPU deployments — and the engine still runs."""
    store = _store(t=512, chunks=4)
    eng = OLAEngine(store, QUERIES[:1], _cfg(extract_backend="auto"))
    assert eng.program.extract_pallas == (
        __import__("jax").default_backend() == "tpu")
    state, hist = eng.run(max_rounds=3)
    assert len(hist) >= 1
    # 'auto' must degrade to ref (not raise) for non-linear frozen queries
    eng2 = OLAEngine(store, [Query(agg="sum", expr=SquaredDiff(0, 1),
                                   epsilon=0.05)],
                     _cfg(extract_backend="auto"))
    assert not eng2.program.extract_pallas


def test_pallas_interpret_backend_forced():
    """'pallas-interpret' is a first-class backend (the benchmark's
    correctness lane): it selects the kernel path with the interpreter
    forced regardless of platform."""
    store = _store(t=512, chunks=4)
    eng = OLAEngine(store, QUERIES[:1], _cfg(
        extract_backend="pallas-interpret"))
    assert eng.program.extract_pallas
    assert eng.program._ops_backend == "pallas-interpret"
    state, hist = eng.run(max_rounds=3)
    assert len(hist) >= 1
