"""Streaming slab pipeline: bounded-memory chunk delivery store → kernel.

Contract under test (``EngineConfig.residency="stream"``):

* the :class:`~repro.data.pipeline.SlabPrefetcher` delivers exactly the
  chunks the round's CLAIM step will hand out (host-side ``plan_claims``
  prediction == the jitted claim), with a bounded host cache;
* round-for-round estimates match ``residency="packed"`` **bit-exactly** on
  the ref backend (same gathers, same arithmetic) for the frozen and
  slot-table planes, including mid-scan admission and top-up passes under
  the workload server;
* the slab-streaming Pallas kernel (row tiles instead of whole-chunk VMEM
  windows) matches its oracle and the ref engine to fp32 tolerance;
* an engine run completes on a store whose packed view exceeds the slab
  budget, with peak raw device bytes ≤ 2 slabs + slack (subprocess test —
  clean ``jax.live_arrays`` accounting).
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

jnp = pytest.importorskip("jax.numpy")

from repro.core.engine import EngineConfig, OLAEngine, SlotOLAEngine
from repro.core.queries import (
    Linear,
    Query,
    Range,
    empty_slot_table,
    encode_slot,
    slot_table_set,
)
from repro.data.formats import AsciiFixedFormat
from repro.data.generator import make_synthetic_zipf, store_dataset
from repro.data.pipeline import SlabPrefetcher
from repro.kernels.ops import slot_extract_stream
from repro.serve.ola_server import OLAWorkloadServer, ServerOptions

COEF = tuple(1.0 / (k + 1) for k in range(8))
QUERIES = [
    Query(agg="sum", expr=Linear(COEF), pred=Range(0, 0.0, 0.6e8),
          epsilon=0.04, name="q-sum"),
    Query(agg="count", pred=Range(1, 0.0, 0.7e8), epsilon=0.06,
          name="q-count"),
    Query(agg="avg", expr=Linear(COEF), epsilon=0.05, name="q-avg"),
]


def _store(t=2048, chunks=12, seed=3, directory=None):
    return store_dataset(make_synthetic_zipf(t, 8, seed=seed), chunks,
                         "ascii", uneven=True, directory=directory)


def _cfg(**kw):
    base = dict(num_workers=4, strategy="single_pass", budget_init=32,
                seed=5, cache_cap=16)
    base.update(kw)
    return EngineConfig(**base)


# ---------------------------------------------------------------------------
# SlabPrefetcher unit behaviour
# ---------------------------------------------------------------------------

def test_prefetcher_assembles_claimed_chunks():
    store = _store(t=512, chunks=6)
    pf = SlabPrefetcher(store, num_workers=3, row_multiple=64, lookahead=2)
    try:
        chunk_ids = np.array([4, 0, 2])
        active = np.array([True, False, True])
        slab = np.asarray(pf.assemble(chunk_ids, active))
        assert slab.shape == (3, pf.rows_max, store.codec.record_bytes)
        assert pf.rows_max % 64 == 0
        for w, j in enumerate(chunk_ids):
            raw = store.chunk_bytes(int(j))
            if active[w]:
                np.testing.assert_array_equal(slab[w, : raw.shape[0]], raw)
                assert not slab[w, raw.shape[0]:].any()
            else:
                assert not slab[w].any()   # inactive workers get zero rows
    finally:
        pf.close()


def test_prefetcher_cache_is_bounded_and_hints_warm_it():
    import time

    store = _store(t=512, chunks=8)
    pf = SlabPrefetcher(store, num_workers=2, max_cached_chunks=3)
    try:
        pf.prefetch(range(5))
        deadline = time.time() + 5.0
        while pf.chunk_reads < 5 and time.time() < deadline:
            time.sleep(0.01)
        assert pf.chunk_reads == 5          # hints were read in background
        assert len(pf._cache) <= 3          # LRU stays bounded
        reads = pf.chunk_reads
        pf.assemble(np.array([4, 3]), np.array([True, True]))
        assert pf.chunk_reads == reads      # warm chunks: no re-read
    finally:
        pf.close()


def test_plan_claims_predicts_jitted_claim():
    """The host-side claim prediction must land on exactly the chunks the
    jitted round hands out — the streaming pipeline's correctness anchor."""
    store = _store(t=1024, chunks=10)
    eng = OLAEngine(store, QUERIES[:1], _cfg())
    sched = eng.program.schedule_np
    state = eng.init_state()
    for _ in range(6):
        j_pred, active, head_pred = eng.program.plan_claims(state)
        state, rep = eng.round_fn(32)(state, eng.packed, eng.speeds)
        assert head_pred == int(state.head)
        cur = np.asarray(state.cur)
        # workers that still hold their chunk after the round must hold the
        # predicted one (closed chunks drop the worker back to IDLE)
        holding = cur >= 0
        np.testing.assert_array_equal(sched[cur[holding]], j_pred[holding])
        assert not active[cur == -2].any()  # EXHAUSTED was predicted too


# ---------------------------------------------------------------------------
# Round-for-round parity: stream == packed (bit-exact on ref)
# ---------------------------------------------------------------------------

def _run_engine(residency, store, **cfg_kw):
    eng = OLAEngine(store, QUERIES, _cfg(residency=residency, **cfg_kw))
    state, hist = eng.run(max_rounds=300)
    ests = np.array([np.asarray(r.estimate) for r in hist])
    return eng, state, ests


def test_frozen_stream_matches_packed_bit_exact():
    store = _store()
    _, sp, ep = _run_engine("packed", store)
    eng, ss, es = _run_engine("stream", store)
    assert ep.shape == es.shape
    np.testing.assert_array_equal(ep, es)
    for name in ("m", "ysum", "ysq", "psum"):
        np.testing.assert_array_equal(np.asarray(getattr(sp.stats, name)),
                                      np.asarray(getattr(ss.stats, name)))
    np.testing.assert_array_equal(np.asarray(sp.cache), np.asarray(ss.cache))
    np.testing.assert_array_equal(np.asarray(sp.scan_m),
                                  np.asarray(ss.scan_m))
    assert eng.pipeline.slabs_built == len(es)
    eng.close()


def test_slot_stream_matches_packed_with_midscan_admission():
    store = _store()
    engines = {res: SlotOLAEngine(store, 4, _cfg(residency=res))
               for res in ("packed", "stream")}
    states = {res: e.init_state() for res, e in engines.items()}
    table = empty_slot_table(4, 8)
    table = slot_table_set(table, 0, encode_slot(QUERIES[0], 8,
                                                 plan="single_pass"))
    for r in range(12):
        if r == 3:   # mid-scan admission
            table = slot_table_set(table, 1, encode_slot(
                QUERIES[1], 8, plan="single_pass"))
        for res, e in engines.items():
            b = e.budget_ladder(float(states[res].budget))
            states[res], data = e.round_data(states[res])
            states[res], rep = e.round_fn(b)(
                states[res], table, data, e.speeds)
    for name in ("m", "ysum", "ysq", "psum"):
        np.testing.assert_array_equal(
            np.asarray(getattr(states["packed"].stats, name)),
            np.asarray(getattr(states["stream"].stats, name)))


def test_server_stream_matches_packed_including_topup():
    """End-to-end workload server parity: admission, synopsis seeding, early
    leave, and a top-up pass (the prefetcher re-serves re-opened chunks)."""
    store = _store()
    out = {}
    for res in ("packed", "stream"):
        with OLAWorkloadServer(
                 store, _cfg(residency=res),
                 options=ServerOptions(max_slots=4, synopsis_budget_tuples=256)) as srv:
            srv.submit(QUERIES[0], arrival_t=0.0)
            srv.submit(QUERIES[1], arrival_t=0.0)
            srv.submit(QUERIES[2], arrival_t=0.002)   # joins mid-scan
            results = srv.run(max_rounds=4000)
            assert not srv.truncated
            out[res] = (srv.rounds, srv.topup_passes,
                        [(r.qid, r.estimate, r.tuples_seen) for r in results])
    assert out["packed"][0] == out["stream"][0]       # same round count
    assert out["packed"][1] == out["stream"][1]       # same top-up passes
    for a, b in zip(out["packed"][2], out["stream"][2]):
        assert a[0] == b[0] and a[2] == b[2]
        assert a[1] == b[1] or np.isnan(a[1]) and np.isnan(b[1])


# ---------------------------------------------------------------------------
# Slab-streaming Pallas kernel
# ---------------------------------------------------------------------------

def test_stream_kernel_matches_ref_oracle():
    rng = np.random.default_rng(0)
    w, r, c, b, s = 4, 300, 8, 64, 5    # r % row_tile != 0 exercises padding
    codec = AsciiFixedFormat(c)
    vals = rng.uniform(-1e7, 1e7, (w * r, c))
    slab = jnp.asarray(codec.encode(vals).reshape(w, r, codec.record_bytes))
    idx = rng.integers(0, r, (w, b)).astype(np.int32)
    b_eff = np.array([b, 7, 0, 33], np.int32)
    coeffs = rng.normal(size=(s, c)).astype(np.float32)
    lo = np.full((s, c), -np.inf, np.float32)
    hi = np.full((s, c), np.inf, np.float32)
    lo[:, 0] = rng.uniform(-1e7, 0, s)
    hi[:, 0] = rng.uniform(0, 1e7, s)
    is_count = np.array([0, 1, 0, 0, 1], np.float32)
    gate = np.array([1, 1, 0, 1, 0], np.float32)
    args = (idx, b_eff, coeffs, lo, hi, is_count, gate)

    sr = np.asarray(slot_extract_stream(slab, *args, backend="ref"))
    sp = np.asarray(slot_extract_stream(slab, *args, backend="pallas"))
    np.testing.assert_allclose(sr, sp, rtol=1e-5, atol=1e-3)
    assert np.all(sp[:, 2, 1:] == 0.0)          # gated-off slot contributes 0
    assert np.all(sp[:, :, 0] == b_eff[:, None])    # m column is b_eff

    # duplicated window rows must fold with multiplicity, not 0/1
    idx_dup = np.full((w, b), 5, np.int32)
    sr = np.asarray(slot_extract_stream(slab, idx_dup, *args[1:],
                                        backend="ref"))
    sp = np.asarray(slot_extract_stream(slab, idx_dup, *args[1:],
                                        backend="pallas"))
    np.testing.assert_allclose(sr, sp, rtol=1e-5, atol=1e-3)


def test_stream_engine_pallas_matches_ref():
    """residency="stream" × extract_backend="pallas": the row-tiled kernel
    drives the full engine round to fp32 tolerance against the ref path,
    including the separately-decoded synopsis cache."""
    store = _store(t=1024, chunks=8)
    states, reps = {}, {}
    for be in ("ref", "pallas"):
        eng = OLAEngine(store, QUERIES, _cfg(
            residency="stream", extract_backend=be,
            budget_min=32, budget_max=32))
        s = eng.init_state()
        for _ in range(6):
            s, data = eng.round_data(s)
            s, r = eng.round_fn(32)(s, data, eng.speeds)
        states[be], reps[be] = s, r
        eng.close()
    np.testing.assert_allclose(np.asarray(reps["ref"].estimate),
                               np.asarray(reps["pallas"].estimate),
                               rtol=2e-5, atol=1e-6)
    for name in ("ysum", "ysq", "psum"):
        np.testing.assert_allclose(
            np.asarray(getattr(states["ref"].stats, name)),
            np.asarray(getattr(states["pallas"].stats, name)),
            rtol=2e-5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(states["ref"].cache),
                               np.asarray(states["pallas"].cache), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(states["ref"].scan_m),
                                  np.asarray(states["pallas"].scan_m))


# ---------------------------------------------------------------------------
# Bounded residency: the acceptance criterion
# ---------------------------------------------------------------------------

_RESIDENCY_SCRIPT = r"""
import json
import numpy as np
from repro.core.engine import OLAEngine, EngineConfig
from repro.core.queries import Query, Linear, Range
from repro.data.generator import make_synthetic_zipf, store_dataset
from repro.data.pipeline import device_resident_bytes

# 48 chunks x ~85 rows: packed view ~24x one slab (W=2 workers)
store = store_dataset(make_synthetic_zipf(4096, 8, seed=0), 48, "ascii",
                      uneven=True)
coef = tuple(1.0 / (k + 1) for k in range(8))
q = Query(agg="sum", expr=Linear(coef), pred=Range(0, 0.0, 0.5e8),
          epsilon=0.03)
cfg = EngineConfig(num_workers=2, strategy="single_pass", budget_init=64,
                   budget_min=64, budget_max=64, seed=5, residency="stream")
eng = OLAEngine(store, [q], cfg)
packed_bytes = (store.num_chunks * store.max_chunk_tuples
                * store.codec.record_bytes)
slab_bytes = eng.pipeline.slab_bytes
assert packed_bytes > 2 * slab_bytes, (packed_bytes, slab_bytes)

state = eng.init_state()
peak = 0
rounds = 0
for _ in range(2000):
    b = eng.budget_ladder(float(state.budget))
    state, data = eng.round_data(state)
    state, rep = eng.round_fn(b)(state, data, eng.speeds)
    peak = max(peak, device_resident_bytes(np.uint8))
    rounds += 1
    if bool(rep.all_stopped) or bool(rep.exhausted):
        break
print(json.dumps({
    "rounds": rounds,
    "stopped": bool(rep.all_stopped) or bool(rep.exhausted),
    "peak_u8": peak,
    "slab_bytes": slab_bytes,
    "packed_bytes": packed_bytes,
    "host_cache_chunks": len(eng.pipeline._cache),
    "capacity": eng.pipeline.capacity,
}))
"""


def test_stream_residency_stays_bounded():
    """An engine run completes on a store whose packed view exceeds the slab
    budget, with peak raw device bytes ≤ 2 slabs (double buffer) + slack.
    Subprocess: jax.live_arrays must only see this engine's buffers."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _RESIDENCY_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["stopped"], res
    budget = 2 * res["slab_bytes"] + 65536      # double buffer + slack
    assert res["peak_u8"] <= budget, res
    assert res["peak_u8"] < res["packed_bytes"], res
    assert res["host_cache_chunks"] <= res["capacity"], res


# ---------------------------------------------------------------------------
# Adaptive prefetch lookahead (measured READ/CPU rate ratio)
# ---------------------------------------------------------------------------

class _PacedStore:
    """Store proxy whose raw reads take a fixed wall time (slow-disk sim)."""

    def __init__(self, store, read_delay_s: float):
        self._store = store
        self._delay = read_delay_s

    def __getattr__(self, name):
        return getattr(self._store, name)

    def chunk_bytes(self, j):
        import time

        if self._delay > 0:
            time.sleep(self._delay)
        return self._store.chunk_bytes(j)


def _drive_prefetcher(pf, store, rounds=6, workers=2):
    order = np.arange(store.num_chunks)
    for r in range(rounds):
        ids = order[(r * workers) % store.num_chunks:][:workers]
        if len(ids) < workers:
            ids = order[:workers]
        pf.assemble(ids, np.ones(workers, bool))


def test_adaptive_lookahead_raises_on_slow_reader():
    """A store whose READ is slow relative to the round cadence must drive
    the adaptive lookahead above its base (the reader needs more runway),
    while a fast store leaves it at the base.  ROADMAP PR-3 follow-on."""
    store = _store(t=2048, chunks=12)
    slow = SlabPrefetcher(_PacedStore(store, read_delay_s=0.05),
                          num_workers=2, lookahead=2, adaptive=True,
                          device_put=lambda a: a)
    assert slow.base_lookahead == 2 and slow.max_lookahead >= 4
    _drive_prefetcher(slow, store)
    assert slow.lookahead > 2, (slow.lookahead, slow.read_seconds)
    assert slow.lookahead <= slow.max_lookahead
    # the cache is provisioned for the ceiling, so a raised lookahead never
    # causes prefetch thrash
    assert slow.capacity >= 2 * slow.num_workers + slow.max_lookahead
    slow.close()

    fast = SlabPrefetcher(_PacedStore(store, read_delay_s=0.0),
                          num_workers=2, lookahead=2, adaptive=True,
                          device_put=lambda a: a)
    import time

    order = np.arange(store.num_chunks)
    for r in range(6):
        ids = order[(r * 2) % store.num_chunks:][:2]
        if len(ids) < 2:
            ids = order[:2]
        fast.assemble(ids, np.ones(2, bool))
        time.sleep(0.01)        # compute dominates: reads stay hidden
    assert fast.lookahead == 2, fast.lookahead
    fast.close()


def test_non_adaptive_lookahead_untouched():
    """adaptive=False (the default) must never move the lookahead — the
    parity configuration for existing streaming deployments."""
    store = _store(t=2048, chunks=12)
    pf = SlabPrefetcher(_PacedStore(store, read_delay_s=0.02), num_workers=2,
                        lookahead=3, device_put=lambda a: a)
    _drive_prefetcher(pf, store, rounds=4)
    assert pf.lookahead == 3
    pf.close()
